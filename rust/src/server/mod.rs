//! TCP host interface (paper Fig. 10: the Vitis TCP server that takes
//! images + control from the host and returns results).
//!
//! Protocol: newline-delimited JSON over TCP.
//!
//! Request:  `{"id": 1, "image": [f32...]}`  (H*W*C floats, row-major
//!           channel-last, matching the artifact's input shape) or
//!           `{"cmd": "stats"}` / `{"cmd": "shutdown"}`.
//! Response: `{"id": 1, "class": 3, "logits": [...], "latency_us": 42}`
//!           or `{"stats": {...}}`.
//!
//! Architecture: connection threads only parse/serialise; inference
//! requests flow over an mpsc channel to the serve thread, which owns
//! the backend exclusively. This keeps non-`Send` backends (the PJRT
//! client's internals are `Rc`-based) on one thread — matching the
//! physical reality of a single accelerator device. std::net + threads;
//! tokio is not vendored in this environment.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Inference backend the server fronts: image in, (class, logits) out.
/// Deliberately NOT required to be `Send` — it never leaves the serve
/// thread.
pub trait Backend {
    fn infer(&mut self, image: &[f32]) -> Result<(usize, Vec<f32>)>;
    fn input_len(&self) -> usize;
}

/// Serving statistics.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub total_latency_us: AtomicU64,
}

/// An inference job travelling from a connection thread to the backend.
struct Job {
    id: f64,
    image: Vec<f32>,
    reply: Sender<Json>,
}

pub struct Server<B: Backend> {
    backend: B,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
}

impl<B: Backend> Server<B> {
    pub fn new(backend: B) -> Self {
        Self {
            backend,
            stats: Arc::new(ServerStats::default()),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }

    pub fn stats(&self) -> Arc<ServerStats> {
        self.stats.clone()
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Bind and serve until a shutdown command arrives. `on_bound`
    /// receives the bound address (port 0 => ephemeral, for tests).
    pub fn serve(mut self, addr: &str,
                 on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);

        let (job_tx, job_rx): (Sender<Job>, Receiver<Job>) = channel();
        let mut handles = Vec::new();

        while !self.shutdown.load(Ordering::SeqCst) {
            // Accept new connections (non-blocking).
            match listener.accept() {
                Ok((stream, _)) => {
                    let tx = job_tx.clone();
                    let stats = self.stats.clone();
                    let shutdown = self.shutdown.clone();
                    let input_len = self.backend.input_len();
                    handles.push(std::thread::spawn(move || {
                        let _ = conn_loop(stream, tx, stats, shutdown,
                                          input_len);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => return Err(e.into()),
            }
            // Drain inference jobs on this (backend-owning) thread.
            let mut worked = false;
            while let Ok(job) = job_rx.try_recv() {
                worked = true;
                let t0 = Instant::now();
                let reply = match self.backend.infer(&job.image) {
                    Ok((class, logits)) => {
                        let us = t0.elapsed().as_micros() as u64;
                        self.stats.requests.fetch_add(1, Ordering::SeqCst);
                        self.stats
                            .total_latency_us
                            .fetch_add(us, Ordering::SeqCst);
                        Json::obj(vec![
                            ("id", Json::num(job.id)),
                            ("class", Json::num(class as f64)),
                            ("logits",
                             Json::Arr(logits
                                 .iter()
                                 .map(|&l| Json::num(l as f64))
                                 .collect())),
                            ("latency_us", Json::num(us as f64)),
                        ])
                    }
                    Err(e) => {
                        self.stats.errors.fetch_add(1, Ordering::SeqCst);
                        Json::obj(vec![("error",
                                        Json::str(&e.to_string()))])
                    }
                };
                let _ = job.reply.send(reply);
            }
            if !worked {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        drop(job_tx);
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Per-connection loop: parse lines, ship jobs, write replies.
fn conn_loop(stream: TcpStream, jobs: Sender<Job>,
             stats: Arc<ServerStats>, shutdown: Arc<AtomicBool>,
             input_len: usize) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let reply = match Json::parse(line.trim()) {
            Err(e) => Json::obj(vec![("error", Json::str(&e.to_string()))]),
            Ok(req) => {
                if let Some(cmd) = req.get("cmd").and_then(|c| c.as_str()) {
                    match cmd {
                        "shutdown" => {
                            shutdown.store(true, Ordering::SeqCst);
                            let r = Json::obj(vec![("ok", Json::Bool(true))]);
                            writeln!(out, "{r}")?;
                            return Ok(());
                        }
                        "stats" => Json::obj(vec![(
                            "stats",
                            Json::obj(vec![
                                ("requests",
                                 Json::num(stats.requests
                                     .load(Ordering::SeqCst) as f64)),
                                ("errors",
                                 Json::num(stats.errors
                                     .load(Ordering::SeqCst) as f64)),
                                ("total_latency_us",
                                 Json::num(stats.total_latency_us
                                     .load(Ordering::SeqCst) as f64)),
                            ]),
                        )]),
                        other => Json::obj(vec![(
                            "error",
                            Json::str(&format!("unknown cmd {other}")),
                        )]),
                    }
                } else {
                    match parse_infer(&req, input_len) {
                        Err(msg) => {
                            stats.errors.fetch_add(1, Ordering::SeqCst);
                            Json::obj(vec![("error", Json::str(&msg))])
                        }
                        Ok((id, image)) => {
                            let (tx, rx) = channel();
                            jobs.send(Job { id, image, reply: tx })
                                .map_err(|_| {
                                    anyhow::anyhow!("server shutting down")
                                })?;
                            rx.recv().unwrap_or_else(|_| {
                                Json::obj(vec![(
                                    "error",
                                    Json::str("server shutting down"),
                                )])
                            })
                        }
                    }
                }
            }
        };
        writeln!(out, "{reply}")?;
    }
}

fn parse_infer(req: &Json, input_len: usize)
               -> std::result::Result<(f64, Vec<f32>), String> {
    let id = req.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let image: Vec<f32> = match req.get("image").and_then(|v| v.as_arr()) {
        Some(arr) => arr
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as f32)
            .collect(),
        None => return Err("missing image".to_string()),
    };
    if image.len() != input_len {
        return Err(format!("image len {} != {input_len}", image.len()));
    }
    Ok((id, image))
}

/// Simple blocking client (used by examples + tests).
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self { stream, reader })
    }

    pub fn request(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.stream, "{req}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    pub fn infer(&mut self, id: u64, image: &[f32]) -> Result<Json> {
        let req = Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("image",
             Json::Arr(image.iter().map(|&x| Json::num(x as f64)).collect())),
        ]);
        self.request(&req)
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.request(&Json::obj(vec![("cmd", Json::str("shutdown"))]));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy backend: class = argmax of the 4-pixel image.
    struct Toy;

    impl Backend for Toy {
        fn infer(&mut self, image: &[f32]) -> Result<(usize, Vec<f32>)> {
            let arg = image
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            Ok((arg, image.to_vec()))
        }

        fn input_len(&self) -> usize {
            4
        }
    }

    #[test]
    fn end_to_end_roundtrip() {
        let server = Server::new(Toy);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap();

        let mut c = Client::connect(&addr.to_string()).unwrap();
        let resp = c.infer(7, &[0.1, 0.9, 0.2, 0.3]).unwrap();
        assert_eq!(resp.get("class").unwrap().as_usize(), Some(1));
        assert_eq!(resp.get("id").unwrap().as_f64(), Some(7.0));

        // Wrong image size -> error, server stays up.
        let resp = c.infer(8, &[0.1]).unwrap();
        assert!(resp.get("error").is_some());

        // Stats reflect the traffic.
        let resp = c
            .request(&Json::obj(vec![("cmd", Json::str("stats"))]))
            .unwrap();
        let stats = resp.get("stats").unwrap();
        assert_eq!(stats.get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(stats.get("errors").unwrap().as_usize(), Some(1));

        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn concurrent_clients() {
        let server = Server::new(Toy);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut clients: Vec<_> = (0..4)
            .map(|i| {
                let a = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&a).unwrap();
                    let mut img = [0.0f32; 4];
                    img[i % 4] = 1.0;
                    let resp = c.infer(i as u64, &img).unwrap();
                    resp.get("class").unwrap().as_usize().unwrap()
                })
            })
            .collect();
        let results: Vec<usize> =
            clients.drain(..).map(|h| h.join().unwrap()).collect();
        assert_eq!(results, vec![0, 1, 2, 3]);

        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }
}
