//! TCP host interface (paper Fig. 10: the Vitis TCP server that takes
//! images + control from the host and returns results).
//!
//! # Dense protocol (newline-delimited JSON)
//!
//! Request:  `{"id": 1, "image": [f32...]}`  (H*W*C floats, row-major
//!           channel-last, matching the artifact's input shape) or
//!           `{"cmd": "stats"}` / `{"cmd": "metrics"}` /
//!           `{"cmd": "shutdown"}` / `{"cmd": "events", ...}` (below).
//! Response: `{"id": 1, "class": 3, "logits": [...], "latency_us": 42,
//!           "replica": 0}` or `{"stats": {...}}`.
//!
//! ## `stats` reply schema
//!
//! One JSON line, `{"stats": {...}}` with:
//!
//! ```text
//! requests        u64   requests served across all replicas
//! errors          u64   backend errors + protocol errors
//! shed            u64   events-mode windows refused (queue full)
//! queue_depth     u64   jobs waiting in the shared queue right now
//! queue_capacity  u64   configured queue bound (0 = unbounded)
//! total_latency_us u64  saturating sum of end-to-end latencies
//! latency         obj   {window, mean_us, p50_us, p95_us, p99_us,
//!                        max_us} over the sliding reservoir
//! replicas        arr   one {requests, errors, busy_us, latency_us}
//!                       object per replica, in replica order
//! ```
//!
//! ## `metrics` command
//!
//! `{"cmd": "metrics"}` switches the reply (for that request only) to
//! a multi-line Prometheus-style text exposition, terminated by a
//! `# EOF` line: request/error/shed totals, latency quantiles
//! (`sti_latency_us{quantile="..."}`), queue depth/capacity,
//! per-replica counters, and — when the serving session attached a
//! workload observer — per-layer observed spike density and arrival
//! rate. Under `serve --online-tune` the exposition also carries
//! `sti_retune_total` (generation swaps) and `sti_retune_generation`
//! (the pool generation currently serving). Metric names are tabled
//! in `docs/ARCHITECTURE.md` (Observability).
//!
//! # Event protocol (`mode: "events"`, length-prefixed binary)
//!
//! The native path for the paper's event-driven single-timestep
//! claim: DVS-style address events stream in, are windowed into
//! word-packed spike frames by [`EventStream`], and enter the pipeline
//! without ever materialising a dense `f32` image. A connection opts
//! in with one JSON line:
//!
//! ```text
//! {"cmd": "events", "window": "count:64" | "us:1000"}
//! ```
//!
//! and receives `{"ok": true, "h": H, "w": W, "c": C,
//! "record_bytes": 12, "max_batch_bytes": N}` (or `{"error": ...}` if
//! the backend is dense-only). From then on the connection is binary,
//! both directions framed as `u32 LE payload length` + payload.
//!
//! **Client -> server** payloads are concatenated 12-byte event
//! records (layout in [`crate::codec::stream`]: `x u16, y u16, c u16,
//! reserved u16 = 0, t u32`, all LE, sorted by `t`). A zero-length
//! frame ends the stream: the server flushes the open window, answers
//! everything in flight, sends the summary, and closes.
//!
//! **Server -> client** payloads start with a status byte:
//!
//! ```text
//! status 0 (window classified)
//!      0  u8   status = 0
//!      1  u8   replica that served the window
//!      2  u16  reserved = 0
//!      4  u32  window id (per-connection sequence number)
//!      8  u32  class (argmax)
//!     12  u64  end-to-end latency, µs
//!     20  u32  logit count N
//!     24  f32 x N logits
//! status 1 (window shed — queue full, explicit backpressure)
//!      0  u8   status = 1     1 u8 = 0     2 u16 = 0
//!      4  u32  window id
//! status 2 (error)
//!      0  u8   status = 2     1 u8 = 0     2 u16 = 0
//!      4  u32  window id
//!      8  u32  UTF-8 message length M
//!     12  u8 x M message
//! status 3 (stream summary, last frame before close)
//!      0  u8   status = 3     1 u8 = 0     2 u16 = 0
//!      4  u64  events ingested
//!     12  u64  windows formed
//!     20  u64  windows served
//!     28  u64  windows shed (refused: queue full, or shutdown race)
//! ```
//!
//! `served + shed == windows` always; a window refused because the
//! server was shutting down counts as shed and its reply is an error
//! frame naming the cause.
//!
//! Classified-window (and timeout) replies are written in window
//! order among *accepted* windows; shed and stream-error frames are
//! written immediately at ingest time, so a shed for window N can
//! arrive before the classification of window N-1 — match replies by
//! their window id, not by arrival position. Backpressure is
//! explicit: the shared queue is bounded (`with_queue_capacity`), and
//! a window that finds it full is answered with a shed frame instead
//! of queueing unboundedly — the client decides whether to re-send or
//! drop.
//!
//! # Architecture
//!
//! Connection threads only parse/serialise; inference jobs flow into a
//! shared [`Batcher`] queue drained by the backend worker(s).
//!
//! * [`Server::serve`] — single-pipeline mode: the accept thread owns
//!   the backend exclusively, matching the physical reality of one
//!   accelerator device. Backends need NOT be `Send` here (the PJRT
//!   client's internals are `Rc`-based).
//! * [`Server::serve_pool`] — multi-pipeline mode: N `Send` backend
//!   replicas each drain the shared queue on their own thread, so
//!   request throughput scales with host cores. Per-replica counters
//!   aggregate in [`crate::metrics::PoolMetrics`] and are reported by
//!   the `stats` command, including mean/p50/p95/p99 latency from the
//!   fixed-size reservoir.
//!
//! std::net + threads; tokio is not vendored in this environment.

use std::collections::VecDeque;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::autotune::RetuneLog;
use crate::codec::stream::{DvsEvent, EventStream, WindowPolicy};
use crate::codec::SpikeFrame;
use crate::coordinator::batch::Batcher;
use crate::metrics::{LatencySummary, PoolMetrics};
use crate::supervise::SuperviseStats;
use crate::telemetry::{MetricsRegistry, WorkloadObserver};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Inference backend the server fronts: image in, (class, logits) out.
/// Deliberately NOT required to be `Send` — `serve` keeps it on one
/// thread. `serve_pool` additionally requires `Send` backends.
pub trait Backend {
    fn infer(&mut self, image: &[f32]) -> Result<(usize, Vec<f32>)>;
    fn input_len(&self) -> usize;

    /// Spike-frame inference for the event-driven serving path.
    /// Backends that only accept dense images keep this default;
    /// events-mode connections are then rejected at negotiation
    /// (because [`Backend::frame_shape`] returns `None`).
    fn infer_frame(&mut self, _frame: &SpikeFrame)
                   -> Result<(usize, Vec<f32>)> {
        anyhow::bail!("backend does not accept spike frames")
    }

    /// `(H, W, C)` of the spike frames [`Backend::infer_frame`]
    /// accepts; `None` (the default) disables events mode.
    fn frame_shape(&self) -> Option<(usize, usize, usize)> {
        None
    }
}

/// Serving statistics. Request/latency aggregates are derived from the
/// per-replica [`PoolMetrics`] (single source of truth); the only
/// separate counters are for protocol errors that never reach a
/// replica and events-mode windows shed under backpressure.
#[derive(Debug)]
pub struct ServerStats {
    /// Bad JSON / bad request shape, counted before replica dispatch.
    pub protocol_errors: AtomicU64,
    /// Events-mode windows refused because the bounded queue was full.
    pub shed: AtomicU64,
    /// Connections dropped because a reply write stalled past
    /// [`EVENTS_WRITE_STALL`] (client stopped draining replies).
    pub dropped_write_stall: AtomicU64,
    /// Connections dropped on any other I/O error mid-conversation.
    pub dropped_io: AtomicU64,
    /// Per-replica counters (one entry in single-pipeline mode).
    pub pool: PoolMetrics,
}

impl ServerStats {
    fn new(replicas: usize) -> Self {
        Self {
            protocol_errors: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            dropped_write_stall: AtomicU64::new(0),
            dropped_io: AtomicU64::new(0),
            pool: PoolMetrics::new(replicas),
        }
    }

    pub fn requests(&self) -> u64 {
        self.pool.totals().requests
    }

    /// Backend errors across replicas + protocol-level errors.
    pub fn errors(&self) -> u64 {
        self.pool.totals().errors
            + self.protocol_errors.load(Ordering::SeqCst)
    }

    /// Windows shed under events-mode backpressure.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::SeqCst)
    }

    /// Connections dropped, by cause: `(write_stall, io)`.
    pub fn dropped(&self) -> (u64, u64) {
        (self.dropped_write_stall.load(Ordering::SeqCst),
         self.dropped_io.load(Ordering::SeqCst))
    }

    /// Saturating sum of end-to-end latencies across replicas. Prefer
    /// [`ServerStats::latency`] — mean + percentiles from a bounded
    /// reservoir — for anything beyond a monotone load indicator.
    pub fn total_latency_us(&self) -> u64 {
        self.pool.totals().latency_us
    }

    /// Mean + p50/p95/p99/max latency over recent requests.
    pub fn latency(&self) -> LatencySummary {
        self.pool.latency_summary()
    }
}

/// How long a connection waits for its queued job's reply before
/// reporting a timeout (bounds client hangs across shutdown races and
/// overload; the error message names both causes).
const REPLY_TIMEOUT: Duration = Duration::from_secs(30);

/// Largest accepted binary frame in either direction (events batches
/// and replies); a length prefix past this is a protocol error.
const MAX_EVENT_BATCH_BYTES: u32 = 1 << 20;

/// Events-mode reply status bytes (module docs).
const EV_OK: u8 = 0;
const EV_SHED: u8 = 1;
const EV_ERR: u8 = 2;
const EV_SUMMARY: u8 = 3;

/// What a job carries to the backend: a dense image (JSON protocol)
/// or an already-windowed spike frame (events protocol).
enum JobPayload {
    Dense(Vec<f32>),
    Frame(SpikeFrame),
}

/// An inference job travelling from a connection thread to a backend.
struct Job {
    id: f64,
    payload: JobPayload,
    enqueued_at: Instant,
    reply: Sender<JobReply>,
}

/// Protocol-agnostic job outcome; the JSON and events connection loops
/// each format it for their wire.
struct JobReply {
    id: f64,
    replica: usize,
    latency_us: u64,
    result: std::result::Result<(usize, Vec<f32>), String>,
}

pub struct Server<B: Backend> {
    backends: Vec<B>,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    max_batch: usize,
    max_wait: Duration,
    queue_cap: usize,
    workload: Option<Arc<WorkloadObserver>>,
    retune: Option<Arc<RetuneLog>>,
    supervise: Option<Arc<SuperviseStats>>,
}

impl<B: Backend> Server<B> {
    /// Single-pipeline server (the paper's one-accelerator shape).
    pub fn new(backend: B) -> Self {
        Self::with_backends(vec![backend])
    }

    /// Server fronting a pool of backend replicas. All replicas must
    /// answer identically (same model); the pool only adds throughput.
    pub fn with_backends(backends: Vec<B>) -> Self {
        assert!(!backends.is_empty(), "server needs at least one backend");
        let n = backends.len();
        Self {
            backends,
            stats: Arc::new(ServerStats::new(n)),
            shutdown: Arc::new(AtomicBool::new(false)),
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            queue_cap: 0,
            workload: None,
            retune: None,
            supervise: None,
        }
    }

    /// Tune the shared queue's batching policy.
    pub fn with_queue(mut self, max_batch: usize, max_wait: Duration)
                      -> Self {
        assert!(max_batch > 0);
        self.max_batch = max_batch;
        self.max_wait = max_wait;
        self
    }

    /// Bound the shared queue's depth (0 = unbounded, the default).
    /// Events-mode windows that find the queue full are answered with
    /// an explicit shed frame instead of queueing; the dense JSON path
    /// still always queues (its clients block per request anyway).
    pub fn with_queue_capacity(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }

    /// Attach a workload observer: its per-layer density and arrival
    /// statistics join the `metrics` exposition. The serving session
    /// wires the same observer into its backends so the numbers track
    /// actual served traffic.
    pub fn with_workload(mut self, obs: Arc<WorkloadObserver>) -> Self {
        self.workload = Some(obs);
        self
    }

    /// Attach the online tuner's retune log: swap counters and the
    /// serving generation join the `metrics` exposition
    /// (`sti_retune_total`, `sti_retune_generation`).
    pub fn with_retune(mut self, log: Arc<RetuneLog>) -> Self {
        self.retune = Some(log);
        self
    }

    /// Attach the supervision counters: replica restarts/retirements,
    /// watchdog fires, retune rollbacks, and tuner restarts join the
    /// `metrics` exposition (`sti_replica_restarts_total`,
    /// `sti_watchdog_fires_total`, `sti_retune_rollbacks_total`, ...).
    pub fn with_supervise(mut self, stats: Arc<SuperviseStats>) -> Self {
        self.supervise = Some(stats);
        self
    }

    pub fn replicas(&self) -> usize {
        self.backends.len()
    }

    pub fn stats(&self) -> Arc<ServerStats> {
        self.stats.clone()
    }

    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    fn bind(&self, addr: &str,
            on_bound: impl FnOnce(std::net::SocketAddr))
            -> Result<TcpListener> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        listener.set_nonblocking(true)?;
        on_bound(listener.local_addr()?);
        Ok(listener)
    }

    /// Bind and serve until a shutdown command arrives, draining jobs
    /// on this (backend-owning) thread. `on_bound` receives the bound
    /// address (port 0 => ephemeral, for tests). Uses the first backend
    /// only — use [`Server::serve_pool`] for replica parallelism.
    pub fn serve(mut self, addr: &str,
                 on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = self.bind(addr, on_bound)?;
        let queue: Arc<Batcher<Job>> = Arc::new(Batcher::with_capacity(
            self.max_batch, self.max_wait, self.queue_cap));
        let conn = ConnInfo {
            input_len: self.backends[0].input_len(),
            frame_shape: self.backends[0].frame_shape(),
        };
        let mut handles = Vec::new();

        while !self.shutdown.load(Ordering::SeqCst) {
            accept_connections(&listener, &queue, &self.stats,
                               &self.shutdown, conn, &self.workload,
                               &self.retune, &self.supervise,
                               &mut handles)?;
            // Drain inference jobs on this (backend-owning) thread.
            let batch = queue.try_batch();
            if batch.is_empty() {
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
            for job in batch {
                handle_job(&mut self.backends[0], 0, job, &self.stats);
            }
        }
        reject_pending(&queue);
        for h in handles {
            let _ = h.join();
        }
        // A connection racing the shutdown flag may have pushed after
        // the first drain; it has exited (or timed out) by now, so one
        // final sweep leaves nothing unanswered.
        reject_pending(&queue);
        Ok(())
    }

    /// Total requests served (stats convenience for tests/benches).
    pub fn requests_served(&self) -> u64 {
        self.stats.requests()
    }
}

impl<B: Backend + Send + 'static> Server<B> {
    /// Bind and serve with every backend replica draining the shared
    /// queue on its own worker thread.
    pub fn serve_pool(mut self, addr: &str,
                      on_bound: impl FnOnce(std::net::SocketAddr))
                      -> Result<()> {
        let listener = self.bind(addr, on_bound)?;
        let queue: Arc<Batcher<Job>> = Arc::new(Batcher::with_capacity(
            self.max_batch, self.max_wait, self.queue_cap));
        let conn = ConnInfo {
            input_len: self.backends[0].input_len(),
            frame_shape: self.backends[0].frame_shape(),
        };

        let mut workers = Vec::new();
        for (idx, mut backend) in self.backends.drain(..).enumerate() {
            let queue = queue.clone();
            let stats = self.stats.clone();
            let stop = self.shutdown.clone();
            workers.push(std::thread::spawn(move || {
                loop {
                    let batch = queue.next_batch();
                    if batch.is_empty() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        continue;
                    }
                    for job in batch {
                        handle_job(&mut backend, idx, job, &stats);
                    }
                }
            }));
        }

        let mut handles = Vec::new();
        while !self.shutdown.load(Ordering::SeqCst) {
            accept_connections(&listener, &queue, &self.stats,
                               &self.shutdown, conn, &self.workload,
                               &self.retune, &self.supervise,
                               &mut handles)?;
            std::thread::sleep(Duration::from_millis(1));
        }
        for w in workers {
            let _ = w.join(); // workers drain the queue before exiting
        }
        reject_pending(&queue);
        for h in handles {
            let _ = h.join();
        }
        // Final sweep for jobs pushed in the shutdown race window (the
        // connection threads have all exited or timed out by now).
        reject_pending(&queue);
        Ok(())
    }
}

/// What a connection thread needs to know about the backend.
#[derive(Clone, Copy)]
struct ConnInfo {
    input_len: usize,
    frame_shape: Option<(usize, usize, usize)>,
}

/// Accept pending connections (non-blocking listener).
#[allow(clippy::too_many_arguments)]
fn accept_connections(
    listener: &TcpListener, queue: &Arc<Batcher<Job>>,
    stats: &Arc<ServerStats>, shutdown: &Arc<AtomicBool>,
    conn: ConnInfo, workload: &Option<Arc<WorkloadObserver>>,
    retune: &Option<Arc<RetuneLog>>,
    supervise: &Option<Arc<SuperviseStats>>,
    handles: &mut Vec<std::thread::JoinHandle<()>>) -> Result<()> {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                let queue = queue.clone();
                let stats = stats.clone();
                let shutdown = shutdown.clone();
                let workload = workload.clone();
                let retune = retune.clone();
                let supervise = supervise.clone();
                handles.push(std::thread::spawn(move || {
                    if let Err(e) = conn_loop(stream, queue,
                                              stats.clone(), shutdown,
                                              conn, workload, retune,
                                              supervise) {
                        count_dropped_connection(&stats, &e);
                    }
                }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                return Ok(());
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Classify a connection-loop error for the drop counters: a write
/// timeout ([`EVENTS_WRITE_STALL`] — the client stopped draining
/// replies) versus any other I/O failure. The connection is gone
/// either way; the counters make the silent drop observable
/// (`sti_connections_dropped_total{reason=...}`).
fn count_dropped_connection(stats: &ServerStats, e: &anyhow::Error) {
    let is_stall = e
        .downcast_ref::<std::io::Error>()
        .map(|io| {
            matches!(io.kind(),
                     std::io::ErrorKind::WouldBlock
                     | std::io::ErrorKind::TimedOut)
        })
        .unwrap_or(false);
    if is_stall {
        stats.dropped_write_stall.fetch_add(1, Ordering::SeqCst);
    } else {
        stats.dropped_io.fetch_add(1, Ordering::SeqCst);
    }
}

/// Run one job through a backend, updating aggregate + replica stats.
fn handle_job<B: Backend>(backend: &mut B, replica: usize, job: Job,
                          stats: &ServerStats) {
    let t0 = Instant::now();
    let result = match &job.payload {
        JobPayload::Dense(image) => backend.infer(image),
        JobPayload::Frame(frame) => backend.infer_frame(frame),
    };
    let busy_us = t0.elapsed().as_micros() as u64;
    let latency_us = job.enqueued_at.elapsed().as_micros() as u64;
    let result = match result {
        Ok(ok) => {
            stats.pool.record(replica, latency_us, busy_us);
            Ok(ok)
        }
        Err(e) => {
            stats.pool.record_error(replica);
            Err(e.to_string())
        }
    };
    let _ = job.reply.send(JobReply {
        id: job.id,
        replica,
        latency_us,
        result,
    });
}

/// Error out whatever is still queued at shutdown.
fn reject_pending(queue: &Batcher<Job>) {
    for job in queue.drain_all() {
        let _ = job.reply.send(JobReply {
            id: job.id,
            replica: 0,
            latency_us: 0,
            result: Err("server shutting down".to_string()),
        });
    }
}

/// Format a reply for the JSON protocol.
fn json_reply(r: &JobReply) -> Json {
    match &r.result {
        Ok((class, logits)) => Json::obj(vec![
            ("id", Json::num(r.id)),
            ("class", Json::num(*class as f64)),
            ("logits",
             Json::Arr(logits.iter().map(|&l| Json::num(l as f64))
                 .collect())),
            ("latency_us", Json::num(r.latency_us as f64)),
            ("replica", Json::num(r.replica as f64)),
        ]),
        Err(e) => Json::obj(vec![("error", Json::str(e))]),
    }
}

fn stats_json(stats: &ServerStats, queue_depth: usize,
              queue_capacity: usize) -> Json {
    let per: Vec<Json> = stats
        .pool
        .per_replica()
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("requests", Json::num(s.requests as f64)),
                ("errors", Json::num(s.errors as f64)),
                ("busy_us", Json::num(s.busy_us as f64)),
                ("latency_us", Json::num(s.latency_us as f64)),
            ])
        })
        .collect();
    let lat = stats.latency();
    Json::obj(vec![(
        "stats",
        Json::obj(vec![
            ("requests", Json::num(stats.requests() as f64)),
            ("errors", Json::num(stats.errors() as f64)),
            ("shed", Json::num(stats.shed() as f64)),
            ("queue_depth", Json::num(queue_depth as f64)),
            ("queue_capacity", Json::num(queue_capacity as f64)),
            ("total_latency_us",
             Json::num(stats.total_latency_us() as f64)),
            ("latency",
             Json::obj(vec![
                 ("window", Json::num(lat.window as f64)),
                 ("mean_us", Json::num(lat.mean_us as f64)),
                 ("p50_us", Json::num(lat.p50_us as f64)),
                 ("p95_us", Json::num(lat.p95_us as f64)),
                 ("p99_us", Json::num(lat.p99_us as f64)),
                 ("max_us", Json::num(lat.max_us as f64)),
             ])),
            ("replicas", Json::Arr(per)),
        ]),
    )])
}

/// Render the `metrics` command reply: the serving counters, latency
/// quantiles, queue state, per-replica counters, and (when attached)
/// workload-observer statistics as Prometheus-style text. The
/// exposition's own `# EOF` line doubles as the wire terminator.
fn metrics_text(stats: &ServerStats, queue_depth: usize,
                queue_capacity: usize,
                workload: Option<&WorkloadObserver>,
                retune: Option<&RetuneLog>,
                supervise: Option<&SuperviseStats>) -> String {
    let mut reg = MetricsRegistry::new();
    reg.counter("sti_requests_total", "requests served across replicas")
        .sample(stats.requests() as f64);
    reg.counter("sti_errors_total",
                "backend errors plus protocol errors")
        .sample(stats.errors() as f64);
    reg.counter("sti_shed_total",
                "events-mode windows refused under backpressure")
        .sample(stats.shed() as f64);
    let (stalled, io) = stats.dropped();
    reg.counter("sti_connections_dropped_total",
                "connections dropped mid-conversation, by cause")
        .sample_with(&[("reason", "write_stall")], stalled as f64)
        .sample_with(&[("reason", "io")], io as f64);
    reg.gauge("sti_queue_depth", "jobs waiting in the shared queue")
        .sample(queue_depth as f64);
    reg.gauge("sti_queue_capacity",
              "configured queue bound (0 = unbounded)")
        .sample(queue_capacity as f64);

    let lat = stats.latency();
    if lat.window > 0 {
        reg.gauge("sti_latency_us",
                  "end-to-end latency quantiles over the sliding \
                   reservoir")
            .sample_with(&[("quantile", "0.5")], lat.p50_us as f64)
            .sample_with(&[("quantile", "0.95")], lat.p95_us as f64)
            .sample_with(&[("quantile", "0.99")], lat.p99_us as f64);
        reg.gauge("sti_latency_mean_us", "mean latency over the window")
            .sample(lat.mean_us as f64);
        reg.gauge("sti_latency_max_us", "max latency over the window")
            .sample(lat.max_us as f64);
    }

    let per = stats.pool.per_replica();
    let replica_requests =
        reg.counter("sti_replica_requests_total",
                    "requests served, per replica");
    for (i, s) in per.iter().enumerate() {
        let idx = i.to_string();
        replica_requests
            .sample_with(&[("replica", &idx)], s.requests as f64);
    }
    let replica_busy =
        reg.counter("sti_replica_busy_us_total",
                    "cumulative backend compute time, per replica");
    for (i, s) in per.iter().enumerate() {
        let idx = i.to_string();
        replica_busy.sample_with(&[("replica", &idx)], s.busy_us as f64);
    }

    if let Some(obs) = workload {
        let snap = obs.snapshot();
        reg.counter("sti_frames_observed_total",
                    "frames seen by the workload observer")
            .sample(snap.frames as f64);
        if snap.interarrival_ewma_us > 0.0 {
            reg.gauge("sti_arrival_interval_us",
                      "EWMA inter-arrival time between batches")
                .sample(snap.interarrival_ewma_us);
            reg.gauge("sti_arrival_rate_fps",
                      "EWMA batch arrival rate")
                .sample(snap.rate_fps);
        }
        let density =
            reg.gauge("sti_layer_spike_density",
                      "EWMA observed output spike density, per layer");
        for l in &snap.layers {
            density.sample_with(&[("layer", &l.name)], l.density_ewma);
        }
    }
    if let Some(log) = retune {
        reg.counter("sti_retune_total",
                    "zero-downtime pool generation swaps")
            .sample(log.retunes() as f64);
        reg.gauge("sti_retune_generation",
                  "replica-pool generation currently serving")
            .sample(log.generation() as f64);
    }
    if let Some(sup) = supervise {
        let snap = sup.snapshot();
        reg.counter("sti_replica_restarts_total",
                    "replica workers restarted after a caught panic")
            .sample(snap.replica_restarts as f64);
        reg.counter("sti_replicas_retired_total",
                    "replica workers retired past the restart budget")
            .sample(snap.replicas_retired as f64);
        reg.counter("sti_watchdog_fires_total",
                    "streamed frames aborted and recovered serially")
            .sample(snap.watchdog_fires as f64);
        reg.counter("sti_retune_rollbacks_total",
                    "retune swaps rolled back by the health probe")
            .sample(snap.retune_rollbacks as f64);
        reg.counter("sti_tuner_restarts_total",
                    "online-tuner control loops restarted after a \
                     caught panic")
            .sample(snap.tuner_restarts as f64);
    }
    reg.render()
}

/// Per-connection loop: parse lines, ship jobs, write replies. An
/// `events` command hands the connection over to the binary
/// `events_loop`.
#[allow(clippy::too_many_arguments)]
fn conn_loop(stream: TcpStream, queue: Arc<Batcher<Job>>,
             stats: Arc<ServerStats>, shutdown: Arc<AtomicBool>,
             conn: ConnInfo, workload: Option<Arc<WorkloadObserver>>,
             retune: Option<Arc<RetuneLog>>,
             supervise: Option<Arc<SuperviseStats>>)
             -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let reply = match Json::parse(line.trim()) {
            Err(e) => Json::obj(vec![("error", Json::str(&e.to_string()))]),
            Ok(req) => {
                if let Some(cmd) = req.get("cmd").and_then(|c| c.as_str()) {
                    match cmd {
                        "shutdown" => {
                            shutdown.store(true, Ordering::SeqCst);
                            let r = Json::obj(vec![("ok", Json::Bool(true))]);
                            writeln!(out, "{r}")?;
                            return Ok(());
                        }
                        "stats" => stats_json(&stats, queue.len(),
                                              queue.capacity),
                        "metrics" => {
                            let text = metrics_text(
                                &stats, queue.len(), queue.capacity,
                                workload.as_deref(),
                                retune.as_deref(),
                                supervise.as_deref());
                            out.write_all(text.as_bytes())?;
                            continue;
                        }
                        "events" => {
                            let window = req
                                .get("window")
                                .and_then(|w| w.as_str())
                                .unwrap_or("us:1000");
                            match (conn.frame_shape,
                                   WindowPolicy::parse(window)) {
                                (None, _) => Json::obj(vec![(
                                    "error",
                                    Json::str("backend does not accept \
                                               spike events"),
                                )]),
                                (_, None) => Json::obj(vec![(
                                    "error",
                                    Json::str(&format!(
                                        "bad window {window:?} (count:N \
                                         or us:N)")),
                                )]),
                                (Some(shape), Some(policy)) => {
                                    let (h, w, c) = shape;
                                    let r = Json::obj(vec![
                                        ("ok", Json::Bool(true)),
                                        ("h", Json::num(h as f64)),
                                        ("w", Json::num(w as f64)),
                                        ("c", Json::num(c as f64)),
                                        ("record_bytes",
                                         Json::num(
                                             DvsEvent::WIRE_BYTES as f64)),
                                        ("max_batch_bytes",
                                         Json::num(
                                             MAX_EVENT_BATCH_BYTES as f64)),
                                    ]);
                                    writeln!(out, "{r}")?;
                                    return events_loop(
                                        &mut reader, &mut out, &queue,
                                        &stats, &shutdown, shape, policy);
                                }
                            }
                        }
                        other => Json::obj(vec![(
                            "error",
                            Json::str(&format!("unknown cmd {other}")),
                        )]),
                    }
                } else {
                    match parse_infer(&req, conn.input_len) {
                        Err(msg) => {
                            stats.protocol_errors
                                .fetch_add(1, Ordering::SeqCst);
                            Json::obj(vec![("error", Json::str(&msg))])
                        }
                        Ok((id, image)) => {
                            if shutdown.load(Ordering::SeqCst) {
                                Json::obj(vec![(
                                    "error",
                                    Json::str("server shutting down"),
                                )])
                            } else {
                                let (tx, rx) = channel();
                                queue.push(Job {
                                    id,
                                    payload: JobPayload::Dense(image),
                                    enqueued_at: Instant::now(),
                                    reply: tx,
                                });
                                match rx.recv_timeout(REPLY_TIMEOUT) {
                                    Ok(r) => json_reply(&r),
                                    Err(_) => Json::obj(vec![(
                                        "error",
                                        Json::str("request timed out \
                                                   (overloaded or \
                                                   shutting down)"),
                                    )]),
                                }
                            }
                        }
                    }
                }
            }
        };
        writeln!(out, "{reply}")?;
    }
}

/// Write one length-prefixed binary frame.
fn write_frame(out: &mut impl Write, payload: &[u8])
               -> std::io::Result<()> {
    out.write_all(&(payload.len() as u32).to_le_bytes())?;
    out.write_all(payload)
}

/// 4-byte status header shared by every events-mode reply.
fn ev_header(status: u8, replica: u8) -> Vec<u8> {
    vec![status, replica, 0, 0]
}

fn ev_ok_payload(window_id: u32, r: &JobReply, class: usize,
                 logits: &[f32]) -> Vec<u8> {
    let mut p = ev_header(EV_OK, r.replica as u8);
    p.extend_from_slice(&window_id.to_le_bytes());
    p.extend_from_slice(&(class as u32).to_le_bytes());
    p.extend_from_slice(&r.latency_us.to_le_bytes());
    p.extend_from_slice(&(logits.len() as u32).to_le_bytes());
    for l in logits {
        p.extend_from_slice(&l.to_le_bytes());
    }
    p
}

fn ev_err_payload(window_id: u32, msg: &str) -> Vec<u8> {
    let mut p = ev_header(EV_ERR, 0);
    p.extend_from_slice(&window_id.to_le_bytes());
    p.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    p.extend_from_slice(msg.as_bytes());
    p
}

fn ev_reply_payload(window_id: u32, r: &JobReply) -> Vec<u8> {
    match &r.result {
        Ok((class, logits)) => ev_ok_payload(window_id, r, *class, logits),
        Err(e) => ev_err_payload(window_id, e),
    }
}

/// How often the events loop wakes from a quiet socket to stream back
/// finished replies (a blocking read would otherwise delay them until
/// the client's next batch).
const EVENTS_IDLE_POLL: Duration = Duration::from_millis(10);

/// How long an events-mode reply write may stall before the server
/// drops the connection (a client that never reads replies would
/// otherwise deadlock the connection thread once both TCP buffers
/// fill).
const EVENTS_WRITE_STALL: Duration = Duration::from_secs(5);

/// Read exactly `buf.len()` bytes, invoking `on_idle` on every read
/// timeout so the caller can stream back finished replies while the
/// client is quiet. `Ok(false)` = clean EOF before the first byte;
/// EOF mid-buffer is an `UnexpectedEof` error.
fn read_full(reader: &mut BufReader<TcpStream>, buf: &mut [u8],
             mut on_idle: impl FnMut() -> std::io::Result<()>)
             -> std::io::Result<bool> {
    let mut off = 0;
    while off < buf.len() {
        match reader.read(&mut buf[off..]) {
            Ok(0) => {
                if off == 0 {
                    return Ok(false);
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "client closed mid-frame"));
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut => {
                on_idle()?;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(true)
}

/// One idle-poll tick of the events loop: bail out on server shutdown
/// (so the accept thread's join never waits on a quiet connection),
/// otherwise stream back finished replies.
fn idle_tick(shutdown: &AtomicBool,
             pending: &mut VecDeque<(u32, Receiver<JobReply>)>,
             out: &mut TcpStream) -> std::io::Result<()> {
    if shutdown.load(Ordering::SeqCst) {
        return Err(std::io::Error::new(std::io::ErrorKind::Other,
                                       "server shutting down"));
    }
    drain_ready(pending, out)
}

/// Write every reply whose job already finished, preserving window
/// order among accepted windows.
fn drain_ready(pending: &mut VecDeque<(u32, Receiver<JobReply>)>,
               out: &mut TcpStream) -> std::io::Result<()> {
    loop {
        let ready = match pending.front() {
            Some((_, rx)) => match rx.try_recv() {
                Ok(r) => Some(Ok(r)),
                Err(TryRecvError::Empty) => None,
                Err(TryRecvError::Disconnected) => Some(Err(())),
            },
            None => None,
        };
        let Some(res) = ready else { return Ok(()) };
        let (wid, _rx) = pending.pop_front().expect("front checked");
        let payload = match res {
            Ok(r) => ev_reply_payload(wid, &r),
            Err(()) => ev_err_payload(wid, "server shutting down"),
        };
        write_frame(out, &payload)?;
    }
}

/// The binary events-mode connection loop (protocol in module docs):
/// read event batches, window them through [`EventStream`], submit
/// completed windows with backpressure, and stream replies back as
/// they finish (in window order among accepted windows; the socket is
/// polled with [`EVENTS_IDLE_POLL`] so replies flow even while the
/// client is quiet).
fn events_loop(reader: &mut BufReader<TcpStream>, out: &mut TcpStream,
               queue: &Arc<Batcher<Job>>, stats: &Arc<ServerStats>,
               shutdown: &Arc<AtomicBool>,
               shape: (usize, usize, usize), policy: WindowPolicy)
               -> Result<()> {
    let mut stream = EventStream::new(shape.0, shape.1, shape.2, policy)?;
    reader.get_ref().set_read_timeout(Some(EVENTS_IDLE_POLL))?;
    // A client that streams events without ever reading replies would
    // eventually wedge this thread in write_frame (both TCP buffers
    // full) while the client blocks writing — a mutual deadlock. A
    // write timeout converts that into a dropped connection instead:
    // clients must drain replies at least every few seconds.
    out.set_write_timeout(Some(EVENTS_WRITE_STALL))?;
    let mut pending: VecDeque<(u32, Receiver<JobReply>)> = VecDeque::new();
    let mut next_window = 0u32;
    let mut served = 0u64;
    let mut shed = 0u64;
    let mut len4 = [0u8; 4];
    let mut buf: Vec<u8> = Vec::new();

    // Submit one completed window (or shed it) and report the outcome
    // frames this can already write.
    let submit = |frame: SpikeFrame, wid: u32,
                  pending: &mut VecDeque<(u32, Receiver<JobReply>)>,
                  served: &mut u64, shed: &mut u64,
                  out: &mut TcpStream|
     -> std::io::Result<()> {
        if shutdown.load(Ordering::SeqCst) {
            // Refused, not served: count as shed so the summary
            // invariant served + shed == windows holds (the reply is
            // an error frame naming the real cause).
            *shed += 1;
            return write_frame(
                out, &ev_err_payload(wid, "server shutting down"));
        }
        let (tx, rx) = channel();
        let job = Job {
            id: wid as f64,
            payload: JobPayload::Frame(frame),
            enqueued_at: Instant::now(),
            reply: tx,
        };
        match queue.try_push(job) {
            Ok(()) => {
                *served += 1;
                pending.push_back((wid, rx));
                Ok(())
            }
            Err(_) => {
                *shed += 1;
                stats.shed.fetch_add(1, Ordering::SeqCst);
                let mut p = ev_header(EV_SHED, 0);
                p.extend_from_slice(&wid.to_le_bytes());
                write_frame(out, &p)
            }
        }
    };

    loop {
        match read_full(reader, &mut len4,
                        || idle_tick(shutdown, &mut pending, out)) {
            Ok(true) => {}
            // Client closed (or broke) mid-stream, or the server is
            // shutting down: stop; nobody is left to answer.
            Ok(false) | Err(_) => return Ok(()),
        }
        let len = u32::from_le_bytes(len4);
        if len == 0 {
            // End of stream: flush the open window, answer everything
            // in flight (in order), then the summary, then close.
            if let Some(f) = stream.flush() {
                let frame = f.clone();
                let wid = next_window;
                next_window += 1;
                submit(frame, wid, &mut pending, &mut served, &mut shed,
                       out)?;
            }
            while let Some((wid, rx)) = pending.pop_front() {
                let payload = match rx.recv_timeout(REPLY_TIMEOUT) {
                    Ok(r) => ev_reply_payload(wid, &r),
                    Err(_) => ev_err_payload(
                        wid,
                        "request timed out (overloaded or shutting down)"),
                };
                write_frame(out, &payload)?;
            }
            let st = stream.stats();
            let mut p = ev_header(EV_SUMMARY, 0);
            p.extend_from_slice(&st.events.to_le_bytes());
            p.extend_from_slice(&st.windows.to_le_bytes());
            p.extend_from_slice(&served.to_le_bytes());
            p.extend_from_slice(&shed.to_le_bytes());
            write_frame(out, &p)?;
            return Ok(());
        }
        if len > MAX_EVENT_BATCH_BYTES
            || len as usize % DvsEvent::WIRE_BYTES != 0
        {
            stats.protocol_errors.fetch_add(1, Ordering::SeqCst);
            write_frame(out, &ev_err_payload(
                next_window,
                &format!("bad event batch length {len}")))?;
            return Ok(()); // framing is broken; close
        }
        buf.resize(len as usize, 0);
        if !read_full(reader, &mut buf,
                      || idle_tick(shutdown, &mut pending, out))? {
            return Ok(()); // client closed between header and payload
        }
        for rec in buf.chunks_exact(DvsEvent::WIRE_BYTES) {
            let pushed = DvsEvent::from_wire(rec)
                .and_then(|ev| stream.push(ev));
            match pushed {
                Ok(false) => {}
                Ok(true) => {
                    let frame = stream.window().clone();
                    let wid = next_window;
                    next_window += 1;
                    submit(frame, wid, &mut pending, &mut served,
                           &mut shed, out)?;
                }
                Err(e) => {
                    stats.protocol_errors.fetch_add(1, Ordering::SeqCst);
                    write_frame(out, &ev_err_payload(
                        next_window, &e.to_string()))?;
                    return Ok(()); // stream contract broken; close
                }
            }
        }
        // Stream back whatever already finished before the next read
        // (the idle poll handles the quiet-client case).
        drain_ready(&mut pending, out)?;
    }
}

fn parse_infer(req: &Json, input_len: usize)
               -> std::result::Result<(f64, Vec<f32>), String> {
    let id = req.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let image: Vec<f32> = match req.get("image").and_then(|v| v.as_arr()) {
        Some(arr) => arr
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as f32)
            .collect(),
        None => return Err("missing image".to_string()),
    };
    if image.len() != input_len {
        return Err(format!("image len {} != {input_len}", image.len()));
    }
    Ok((id, image))
}

/// One parsed events-mode reply on the client side.
#[derive(Debug, Clone, PartialEq)]
pub enum EventReply {
    /// A window was classified.
    Window {
        window_id: u32,
        replica: usize,
        class: usize,
        logits: Vec<f32>,
        latency_us: u64,
    },
    /// The window was shed under backpressure (queue full).
    Shed { window_id: u32 },
    /// The window (or the stream) errored.
    Error { window_id: u32, msg: String },
    /// End-of-stream summary.
    Summary(EventSummary),
}

/// The events-mode end-of-stream summary frame.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EventSummary {
    pub events: u64,
    pub windows: u64,
    pub served: u64,
    pub shed: u64,
}

fn le_u32(b: &[u8], at: usize) -> Result<u32> {
    anyhow::ensure!(b.len() >= at + 4, "short reply frame");
    Ok(u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]]))
}

fn le_u64(b: &[u8], at: usize) -> Result<u64> {
    anyhow::ensure!(b.len() >= at + 8, "short reply frame");
    let mut w = [0u8; 8];
    w.copy_from_slice(&b[at..at + 8]);
    Ok(u64::from_le_bytes(w))
}

fn parse_event_reply(p: &[u8]) -> Result<EventReply> {
    anyhow::ensure!(p.len() >= 4, "reply frame under 4 bytes");
    match p[0] {
        EV_OK => {
            let n = le_u32(p, 20)? as usize;
            anyhow::ensure!(p.len() >= 24 + n * 4, "short logits");
            let logits = (0..n)
                .map(|i| {
                    let at = 24 + i * 4;
                    f32::from_le_bytes([p[at], p[at + 1], p[at + 2],
                                        p[at + 3]])
                })
                .collect();
            Ok(EventReply::Window {
                window_id: le_u32(p, 4)?,
                replica: p[1] as usize,
                class: le_u32(p, 8)? as usize,
                latency_us: le_u64(p, 12)?,
                logits,
            })
        }
        EV_SHED => Ok(EventReply::Shed { window_id: le_u32(p, 4)? }),
        EV_ERR => {
            let m = le_u32(p, 8)? as usize;
            anyhow::ensure!(p.len() >= 12 + m, "short error message");
            Ok(EventReply::Error {
                window_id: le_u32(p, 4)?,
                msg: String::from_utf8_lossy(&p[12..12 + m]).into_owned(),
            })
        }
        EV_SUMMARY => Ok(EventReply::Summary(EventSummary {
            events: le_u64(p, 4)?,
            windows: le_u64(p, 12)?,
            served: le_u64(p, 20)?,
            shed: le_u64(p, 28)?,
        })),
        other => anyhow::bail!("unknown reply status {other}"),
    }
}

/// Retry schedule for [`Client::submit_with_retry`]: bounded attempt
/// count with jittered exponential backoff between attempts.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total attempts, including the first (minimum 1).
    pub max_attempts: u32,
    /// Backoff budget before the second attempt; doubles per retry.
    pub base_backoff: Duration,
    /// Ceiling on any single backoff sleep.
    pub max_backoff: Duration,
    /// Seed for the jitter RNG so retry timing is reproducible.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 4,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(500),
            seed: 0x7E72_11ED,
        }
    }
}

impl RetryPolicy {
    /// Sleep before retry number `attempt` (1 = first retry): uniform
    /// jitter in `[b/2, b]` where `b = base * 2^(attempt-1)`, capped
    /// at `max_backoff`.
    fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = self
            .base_backoff
            .saturating_mul(1u32 << (attempt - 1).min(16));
        let cap = exp.min(self.max_backoff).as_micros() as u64;
        let half = cap / 2;
        let jitter = rng.below((cap - half + 1) as usize) as u64;
        Duration::from_micros(half + jitter)
    }
}

/// True when an inference reply's error message indicates transient
/// overload a later attempt may clear: explicit shed, a full queue, or
/// a reply timeout. Terminal conditions (server shutting down,
/// protocol errors) are not retried.
fn reply_is_retryable(err: &str) -> bool {
    if err.contains("timed out") || err.contains("shed")
        || err.contains("queue full")
    {
        return true;
    }
    false
}

/// Simple blocking client (used by examples + tests). Speaks both the
/// JSON protocol ([`Client::infer`]) and, after
/// [`Client::start_events`], the binary events protocol.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Self { stream, reader })
    }

    pub fn request(&mut self, req: &Json) -> Result<Json> {
        writeln!(self.stream, "{req}")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    pub fn infer(&mut self, id: u64, image: &[f32]) -> Result<Json> {
        let req = Json::obj(vec![
            ("id", Json::num(id as f64)),
            ("image",
             Json::Arr(image.iter().map(|&x| Json::num(x as f64)).collect())),
        ]);
        self.request(&req)
    }

    /// [`Client::infer`] with bounded retries: replies whose `error`
    /// field indicates transient overload (shed, queue full, reply
    /// timeout) are retried up to `policy.max_attempts` total
    /// attempts with jittered exponential backoff between them.
    /// Transport errors and terminal replies (e.g. "server shutting
    /// down") are returned immediately; when the budget runs out, the
    /// last reply is returned as-is for the caller to inspect.
    pub fn submit_with_retry(&mut self, id: u64, image: &[f32],
                             policy: &RetryPolicy) -> Result<Json> {
        let mut rng = Rng::new(policy.seed ^ id);
        let attempts = policy.max_attempts.max(1);
        let mut reply = self.infer(id, image)?;
        for attempt in 1..attempts {
            let retryable = reply
                .get("error")
                .and_then(|e| e.as_str())
                .is_some_and(reply_is_retryable);
            if !retryable {
                return Ok(reply);
            }
            std::thread::sleep(policy.backoff(attempt, &mut rng));
            reply = self.infer(id, image)?;
        }
        Ok(reply)
    }

    /// Switch this connection to the binary events protocol; returns
    /// the `(h, w, c)` frame shape the server will window into.
    pub fn start_events(&mut self, window: WindowPolicy)
                        -> Result<(usize, usize, usize)> {
        let req = Json::obj(vec![
            ("cmd", Json::str("events")),
            ("window", Json::str(&window.to_string())),
        ]);
        let resp = self.request(&req)?;
        if let Some(err) = resp.get("error").and_then(|e| e.as_str()) {
            anyhow::bail!("events mode refused: {err}");
        }
        let dim = |k: &str| {
            resp.get(k).and_then(|v| v.as_usize()).ok_or_else(|| {
                anyhow::anyhow!("events handshake missing {k}: {resp}")
            })
        };
        Ok((dim("h")?, dim("w")?, dim("c")?))
    }

    /// Send a batch of sorted events, automatically split into
    /// length-prefixed frames no larger than the server's
    /// `max_batch_bytes` limit (windowing is batch-boundary-agnostic,
    /// so the split is invisible to the server).
    pub fn send_events(&mut self, events: &[DvsEvent]) -> Result<()> {
        let per_frame =
            MAX_EVENT_BATCH_BYTES as usize / DvsEvent::WIRE_BYTES;
        for chunk in events.chunks(per_frame.max(1)) {
            let payload = crate::codec::stream::encode_events(chunk);
            write_frame(&mut self.stream, &payload)?;
        }
        Ok(())
    }

    /// Read the next events-mode reply frame.
    pub fn read_event_reply(&mut self) -> Result<EventReply> {
        let mut len4 = [0u8; 4];
        self.reader.read_exact(&mut len4)?;
        let len = u32::from_le_bytes(len4);
        anyhow::ensure!(len <= MAX_EVENT_BATCH_BYTES,
                        "oversized reply frame ({len} bytes)");
        let mut buf = vec![0u8; len as usize];
        self.reader.read_exact(&mut buf)?;
        parse_event_reply(&buf)
    }

    /// End the event stream: the server flushes, answers every window
    /// still in flight, and closes with a summary. Returns all replies
    /// received from now on plus the summary.
    pub fn finish_events(&mut self)
                         -> Result<(Vec<EventReply>, EventSummary)> {
        write_frame(&mut self.stream, &[])?;
        let mut replies = Vec::new();
        loop {
            match self.read_event_reply()? {
                EventReply::Summary(s) => return Ok((replies, s)),
                r => replies.push(r),
            }
        }
    }

    /// Fetch the Prometheus-style metrics exposition: sends
    /// `{"cmd": "metrics"}` and reads lines up to and including the
    /// `# EOF` terminator. Returns the full text (terminator
    /// included, as Prometheus expects).
    pub fn metrics(&mut self) -> Result<String> {
        writeln!(self.stream,
                 "{}", Json::obj(vec![("cmd", Json::str("metrics"))]))?;
        let mut text = String::new();
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                anyhow::bail!("connection closed before # EOF");
            }
            text.push_str(&line);
            if line.trim_end() == "# EOF" {
                return Ok(text);
            }
        }
    }

    pub fn shutdown(&mut self) -> Result<()> {
        let _ = self.request(&Json::obj(vec![("cmd", Json::str("shutdown"))]));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::stream::synth_events;

    /// Toy backend: class = argmax of the 4-pixel image.
    struct Toy;

    impl Backend for Toy {
        fn infer(&mut self, image: &[f32]) -> Result<(usize, Vec<f32>)> {
            let arg = image
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap();
            Ok((arg, image.to_vec()))
        }

        fn input_len(&self) -> usize {
            4
        }
    }

    /// Frame-capable toy: class = spike count % 10, one logit = count.
    /// `delay_ms` simulates a slow accelerator for backpressure tests.
    struct FrameToy {
        shape: (usize, usize, usize),
        delay_ms: u64,
    }

    impl Backend for FrameToy {
        fn infer(&mut self, image: &[f32]) -> Result<(usize, Vec<f32>)> {
            Ok((0, image.to_vec()))
        }

        fn input_len(&self) -> usize {
            self.shape.0 * self.shape.1 * self.shape.2
        }

        fn infer_frame(&mut self, frame: &SpikeFrame)
                       -> Result<(usize, Vec<f32>)> {
            if self.delay_ms > 0 {
                std::thread::sleep(Duration::from_millis(self.delay_ms));
            }
            let count = frame.count();
            Ok((count % 10, vec![count as f32]))
        }

        fn frame_shape(&self) -> Option<(usize, usize, usize)> {
            Some(self.shape)
        }
    }

    #[test]
    fn end_to_end_roundtrip() {
        let server = Server::new(Toy);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap();

        let mut c = Client::connect(&addr.to_string()).unwrap();
        let resp = c.infer(7, &[0.1, 0.9, 0.2, 0.3]).unwrap();
        assert_eq!(resp.get("class").unwrap().as_usize(), Some(1));
        assert_eq!(resp.get("id").unwrap().as_f64(), Some(7.0));

        // Wrong image size -> error, server stays up.
        let resp = c.infer(8, &[0.1]).unwrap();
        assert!(resp.get("error").is_some());

        // Stats reflect the traffic, including the latency summary.
        let resp = c
            .request(&Json::obj(vec![("cmd", Json::str("stats"))]))
            .unwrap();
        let stats = resp.get("stats").unwrap();
        assert_eq!(stats.get("requests").unwrap().as_usize(), Some(1));
        assert_eq!(stats.get("errors").unwrap().as_usize(), Some(1));
        let lat = stats.get("latency").expect("latency summary");
        assert_eq!(lat.get("window").unwrap().as_usize(), Some(1));
        assert!(lat.get("p99_us").unwrap().as_f64().unwrap()
                >= lat.get("p50_us").unwrap().as_f64().unwrap());
        // One reply covers the whole schema: queue state included.
        assert_eq!(stats.get("queue_depth").unwrap().as_usize(), Some(0));
        assert_eq!(stats.get("queue_capacity").unwrap().as_usize(),
                   Some(0));

        // Dense-only backend refuses events mode. Scoped so the client
        // drops (and its connection thread exits) before shutdown
        // joins the connection threads.
        {
            let mut c2 = Client::connect(&addr.to_string()).unwrap();
            assert!(c2.start_events(WindowPolicy::Count(4)).is_err());
        }

        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn concurrent_clients() {
        let server = Server::new(Toy);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut clients: Vec<_> = (0..4)
            .map(|i| {
                let a = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&a).unwrap();
                    let mut img = [0.0f32; 4];
                    img[i % 4] = 1.0;
                    let resp = c.infer(i as u64, &img).unwrap();
                    resp.get("class").unwrap().as_usize().unwrap()
                })
            })
            .collect();
        let results: Vec<usize> =
            clients.drain(..).map(|h| h.join().unwrap()).collect();
        assert_eq!(results, vec![0, 1, 2, 3]);

        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    /// Four replicas behind one port: every request answered correctly,
    /// per-replica stats sum to the total, and the stats command
    /// reports one entry per replica.
    #[test]
    fn replica_pool_serves_concurrent_clients() {
        let server =
            Server::with_backends(vec![Toy, Toy, Toy, Toy])
                .with_queue(4, Duration::from_millis(2));
        assert_eq!(server.replicas(), 4);
        let stats = server.stats();
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve_pool("127.0.0.1:0",
                              move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut clients: Vec<_> = (0..8u64)
            .map(|i| {
                let a = addr.clone();
                std::thread::spawn(move || {
                    let mut c = Client::connect(&a).unwrap();
                    let mut got = Vec::new();
                    for j in 0..4u64 {
                        let mut img = [0.0f32; 4];
                        img[((i + j) % 4) as usize] = 1.0;
                        let resp = c.infer(i * 10 + j, &img).unwrap();
                        got.push((
                            resp.get("class").unwrap().as_usize().unwrap(),
                            ((i + j) % 4) as usize,
                        ));
                    }
                    got
                })
            })
            .collect();
        for c in clients.drain(..) {
            for (got, want) in c.join().unwrap() {
                assert_eq!(got, want);
            }
        }

        let totals = stats.pool.totals();
        assert_eq!(totals.requests, 32);
        assert_eq!(stats.requests(), 32);
        assert_eq!(stats.pool.per_replica().len(), 4);
        assert_eq!(stats.latency().count, 32);

        let mut c = Client::connect(&addr).unwrap();
        let resp = c
            .request(&Json::obj(vec![("cmd", Json::str("stats"))]))
            .unwrap();
        let replicas = resp
            .get("stats")
            .and_then(|s| s.get("replicas"))
            .and_then(|r| r.as_arr())
            .expect("per-replica stats present");
        assert_eq!(replicas.len(), 4);
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    /// The `metrics` command renders a Prometheus-style exposition
    /// with serving counters, latency quantiles, queue state, and —
    /// with an observer attached — per-layer workload statistics.
    #[test]
    fn metrics_command_renders_prometheus_text() {
        let obs = Arc::new(WorkloadObserver::new());
        obs.observe(&["conv0".to_string(), "pool1".to_string()],
                    &[0.25, 0.5], 2);
        let server = Server::new(Toy)
            .with_queue_capacity(8)
            .with_workload(obs);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut c = Client::connect(&addr).unwrap();
        let _ = c.infer(1, &[0.4, 0.1, 0.2, 0.3]).unwrap();
        let text = c.metrics().unwrap();
        assert!(text.contains("sti_requests_total 1"), "{text}");
        assert!(text.contains("sti_queue_capacity 8"), "{text}");
        assert!(text.contains("# TYPE sti_latency_us gauge"), "{text}");
        assert!(text.contains("sti_latency_us{quantile=\"0.99\"}"),
                "{text}");
        assert!(text.contains("sti_layer_spike_density{layer=\"conv0\"} \
                               0.25"),
                "{text}");
        assert!(text.contains("sti_frames_observed_total 2"), "{text}");
        assert!(text.contains("sti_connections_dropped_total\
                               {reason=\"write_stall\"} 0"),
                "{text}");
        assert!(text.contains("sti_connections_dropped_total\
                               {reason=\"io\"} 0"),
                "{text}");
        assert!(text.ends_with("# EOF\n"), "{text}");
        // The connection still speaks JSON after a metrics reply.
        let resp = c.infer(2, &[0.9, 0.1, 0.2, 0.3]).unwrap();
        assert_eq!(resp.get("class").unwrap().as_usize(), Some(0));

        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    /// With a retune log attached the exposition carries the swap
    /// counter and serving generation; without one the lines are
    /// absent entirely (metrics stay byte-stable for plain serving).
    #[test]
    fn metrics_expose_retune_counters_when_attached() {
        let log = Arc::new(crate::autotune::RetuneLog::default());
        let server = Server::new(Toy).with_retune(log);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut c = Client::connect(&addr).unwrap();
        let text = c.metrics().unwrap();
        assert!(text.contains("# TYPE sti_retune_total counter"), "{text}");
        assert!(text.contains("sti_retune_total 0"), "{text}");
        assert!(text.contains("sti_retune_generation 0"), "{text}");
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();

        let plain = Server::new(Toy);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            plain.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();
        let mut c = Client::connect(&addr).unwrap();
        let text = c.metrics().unwrap();
        assert!(!text.contains("sti_retune"), "{text}");
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    /// Events mode end to end over the single-pipeline server: binary
    /// handshake, count-windowed ingestion, ordered replies, summary.
    #[test]
    fn events_mode_end_to_end() {
        let server = Server::new(FrameToy { shape: (4, 4, 2),
                                            delay_ms: 0 });
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut c = Client::connect(&addr).unwrap();
        let shape = c.start_events(WindowPolicy::Count(5)).unwrap();
        assert_eq!(shape, (4, 4, 2));
        // 12 distinct events -> windows of 5/5, then a flushed 2.
        let events: Vec<DvsEvent> = (0..12u32)
            .map(|i| DvsEvent {
                x: (i % 4) as u16,
                y: (i / 4 % 4) as u16,
                c: (i % 2) as u16,
                t: i,
            })
            .collect();
        c.send_events(&events[..7]).unwrap();
        c.send_events(&events[7..]).unwrap();
        let (replies, summary) = c.finish_events().unwrap();
        assert_eq!(summary.windows, 3);
        assert_eq!(summary.served, 3);
        assert_eq!(summary.shed, 0);
        assert_eq!(summary.events, 12);
        let classes: Vec<(u32, usize)> = replies
            .iter()
            .map(|r| match r {
                EventReply::Window { window_id, class, logits, .. } => {
                    assert_eq!(logits.len(), 1);
                    (*window_id, *class)
                }
                other => panic!("unexpected reply {other:?}"),
            })
            .collect();
        // Windows arrive in order; distinct events -> count = class.
        assert_eq!(classes, vec![(0, 5), (1, 5), (2, 2)]);

        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    /// Request/response usage: a finished reply streams back while the
    /// client sends nothing further (the idle poll, not the next
    /// batch, delivers it).
    #[test]
    fn events_reply_streams_while_client_idle() {
        let server = Server::new(FrameToy { shape: (4, 4, 2),
                                            delay_ms: 0 });
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut c = Client::connect(&addr).unwrap();
        c.start_events(WindowPolicy::Count(3)).unwrap();
        let evs = [
            DvsEvent { x: 0, y: 0, c: 0, t: 0 },
            DvsEvent { x: 1, y: 1, c: 1, t: 1 },
            DvsEvent { x: 2, y: 2, c: 0, t: 2 },
        ];
        c.send_events(&evs).unwrap();
        // No flush, no further input: the reply must still arrive.
        match c.read_event_reply().unwrap() {
            EventReply::Window { window_id, class, .. } => {
                assert_eq!(window_id, 0);
                assert_eq!(class, 3);
            }
            other => panic!("expected window reply, got {other:?}"),
        }
        let (rest, summary) = c.finish_events().unwrap();
        assert!(rest.is_empty());
        assert_eq!(summary.served, 1);

        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    /// A bounded queue + a slow backend: some windows are shed with an
    /// explicit reply, none hang, and the stats count the shed.
    #[test]
    fn events_backpressure_sheds_explicitly() {
        let server = Server::new(FrameToy { shape: (8, 8, 2),
                                            delay_ms: 40 })
            .with_queue(1, Duration::from_millis(1))
            .with_queue_capacity(1);
        let stats = server.stats();
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut c = Client::connect(&addr).unwrap();
        c.start_events(WindowPolicy::TimeUs(1000)).unwrap();
        // 10 synthetic windows fired as fast as the socket takes them;
        // with 40 ms per inference and queue depth 1, most must shed.
        let events = synth_events(8, 8, 2, 10, 0.3, 1000, 5);
        c.send_events(&events).unwrap();
        let (replies, summary) = c.finish_events().unwrap();
        assert_eq!(summary.windows, 10);
        assert_eq!(summary.served + summary.shed, 10);
        assert!(summary.shed >= 1, "expected shedding, got {summary:?}");
        assert!(summary.served >= 1, "some window must still serve");
        assert_eq!(stats.shed(), summary.shed);
        let shed_replies = replies
            .iter()
            .filter(|r| matches!(r, EventReply::Shed { .. }))
            .count() as u64;
        // Shed frames may arrive before finish_events' reading starts
        // only on this connection, so all of them are in `replies`.
        assert_eq!(shed_replies, summary.shed);

        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    /// Protocol violations (unsorted events) get an error reply and a
    /// protocol_errors tick instead of a hang.
    #[test]
    fn events_protocol_violation_errors_out() {
        let server = Server::new(FrameToy { shape: (4, 4, 2),
                                            delay_ms: 0 });
        let stats = server.stats();
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();

        let mut c = Client::connect(&addr).unwrap();
        c.start_events(WindowPolicy::Count(100)).unwrap();
        let unsorted = vec![
            DvsEvent { x: 0, y: 0, c: 0, t: 10 },
            DvsEvent { x: 1, y: 1, c: 1, t: 5 },
        ];
        c.send_events(&unsorted).unwrap();
        match c.read_event_reply().unwrap() {
            EventReply::Error { msg, .. } => {
                assert!(msg.contains("unsorted"), "{msg}");
            }
            other => panic!("expected error, got {other:?}"),
        }
        assert_eq!(stats.protocol_errors.load(Ordering::SeqCst), 1);

        let mut c = Client::connect(&addr).unwrap();
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }

    /// `submit_with_retry` against a scripted flaky server: two
    /// retryable overload replies, then success on the third attempt.
    /// A terminal error ("server shutting down") is returned on the
    /// first attempt without burning the retry budget.
    #[test]
    fn submit_with_retry_survives_a_flaky_server() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let script = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader =
                BufReader::new(stream.try_clone().unwrap());
            let mut out = stream;
            let mut served = 0u32;
            let mut line = String::new();
            loop {
                line.clear();
                if reader.read_line(&mut line).unwrap() == 0 {
                    return served;
                }
                served += 1;
                let reply = match served {
                    1 => r#"{"error": "window shed (queue full)"}"#,
                    2 => r#"{"error": "request timed out (overloaded)"}"#,
                    3 => r#"{"id": 7, "class": 3}"#,
                    _ => r#"{"error": "server shutting down"}"#,
                };
                writeln!(out, "{reply}").unwrap();
            }
        });

        let mut c = Client::connect(&addr).unwrap();
        let policy = RetryPolicy {
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(4),
            ..RetryPolicy::default()
        };
        let ok = c.submit_with_retry(7, &[0.0; 4], &policy).unwrap();
        assert_eq!(ok.get("class").and_then(|v| v.as_usize()), Some(3));

        let term = c.submit_with_retry(8, &[0.0; 4], &policy).unwrap();
        assert_eq!(term.get("error").and_then(|e| e.as_str()),
                   Some("server shutting down"));
        drop(c);
        assert_eq!(script.join().unwrap(), 4);
    }

    /// Retry classification: overload is retryable, terminal and
    /// protocol conditions are not.
    #[test]
    fn retryable_reply_classification() {
        assert!(reply_is_retryable("window shed (queue full)"));
        assert!(reply_is_retryable(
            "request timed out (overloaded or shutting down)"));
        assert!(!reply_is_retryable("server shutting down"));
        assert!(!reply_is_retryable("bad image length"));
    }

    /// With supervision stats attached the exposition carries the
    /// restart/watchdog/rollback counters; a plain server emits none
    /// of them (byte-stable metrics for unsupervised serving).
    #[test]
    fn metrics_expose_supervision_counters_when_attached() {
        let sup = Arc::new(SuperviseStats::default());
        sup.replica_restarts.fetch_add(2, Ordering::SeqCst);
        sup.watchdog_fires.fetch_add(1, Ordering::SeqCst);
        let server = Server::new(Toy).with_supervise(sup);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();
        let mut c = Client::connect(&addr).unwrap();
        let text = c.metrics().unwrap();
        assert!(text.contains("sti_replica_restarts_total 2"), "{text}");
        assert!(text.contains("sti_replicas_retired_total 0"), "{text}");
        assert!(text.contains("sti_watchdog_fires_total 1"), "{text}");
        assert!(text.contains("sti_retune_rollbacks_total 0"), "{text}");
        assert!(text.contains("sti_tuner_restarts_total 0"), "{text}");
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();

        let plain = Server::new(Toy);
        let (tx, rx) = std::sync::mpsc::channel();
        let h = std::thread::spawn(move || {
            plain.serve("127.0.0.1:0", move |addr| tx.send(addr).unwrap())
        });
        let addr = rx.recv().unwrap().to_string();
        let mut c = Client::connect(&addr).unwrap();
        let text = c.metrics().unwrap();
        assert!(!text.contains("sti_replica_restarts_total"), "{text}");
        assert!(!text.contains("sti_watchdog_fires_total"), "{text}");
        c.shutdown().unwrap();
        h.join().unwrap().unwrap();
    }
}
