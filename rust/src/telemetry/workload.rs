//! Rolling workload statistics observed on the serving path.
//!
//! ROADMAP item 5 (online DSE re-tuning) needs the *measured*
//! workload, not the configured one: how sparse the traffic actually
//! is per layer, and how fast frames actually arrive. A
//! [`WorkloadObserver`] sits on the inference path (one `Arc` shared
//! by every pipeline replica and the server), folds each completed
//! frame's per-layer codec ratios into exponential moving averages,
//! and tracks frame inter-arrival times. [`WorkloadObserver::snapshot`]
//! is the read side — the `metrics` server command and
//! `Session::telemetry()` both render it.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// EWMA smoothing factor: each new frame contributes 20%, so the
/// averages track the recent few dozen frames of traffic.
const EWMA_ALPHA: f64 = 0.2;

/// Observations kept per layer for the windowed min/max — enough to
/// cover the traffic the EWMA effectively averages over.
const DENSITY_WINDOW: usize = 64;

/// Rolling statistics of one layer's observed traffic.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerWorkload {
    pub name: String,
    /// EWMA of the layer's codec compression ratio — the
    /// compressed/dense size ratio of its output spikes, the
    /// simulator's measured spike-density proxy (sparser traffic =>
    /// smaller ratio; see `codec`).
    pub density_ewma: f64,
    /// Lowest density in the recent observation window. A wide
    /// [`density_min`](Self::density_min)..[`density_max`](Self::density_max)
    /// spread flags a bimodal workload the EWMA alone would average
    /// into a point neither mode actually hits — the retune policy's
    /// stay-put signal.
    pub density_min: f64,
    /// Highest density in the recent observation window.
    pub density_max: f64,
    /// Frames folded into the average.
    pub frames: u64,
}

impl LayerWorkload {
    /// Window spread (`max - min`): ~0 for steady traffic, large for
    /// bimodal traffic.
    pub fn density_spread(&self) -> f64 {
        self.density_max - self.density_min
    }
}

/// Read-side snapshot of everything the observer tracks.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WorkloadSnapshot {
    /// Frames observed since construction.
    pub frames: u64,
    /// EWMA of the gap between consecutive frame arrivals, µs
    /// (0 until two frames have arrived).
    pub interarrival_ewma_us: f64,
    /// Observed arrival rate derived from the inter-arrival EWMA,
    /// frames/s (0 until two frames have arrived).
    pub rate_fps: f64,
    pub layers: Vec<LayerWorkload>,
}

struct Inner {
    layers: Vec<LayerWorkload>,
    /// Ring of the last [`DENSITY_WINDOW`] raw density observations
    /// per layer (parallel to `layers`), backing the windowed min/max.
    windows: Vec<VecDeque<f64>>,
    interarrival_ewma_us: f64,
}

/// Shared accumulator of measured workload: per-layer spike-density
/// EWMAs plus frame inter-arrival statistics. Writers call
/// [`WorkloadObserver::observe`] per completed frame batch; readers
/// call [`WorkloadObserver::snapshot`] any time without disturbing
/// the averages.
#[derive(Debug)]
pub struct WorkloadObserver {
    epoch: Instant,
    frames: AtomicU64,
    /// Last arrival, µs since epoch, stored value+1 so 0 stays the
    /// "no frame yet" sentinel (the latency-reservoir idiom).
    last_arrival_us: AtomicU64,
    inner: Mutex<Inner>,
}

impl Default for WorkloadObserver {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkloadObserver {
    pub fn new() -> Self {
        Self {
            epoch: Instant::now(),
            frames: AtomicU64::new(0),
            last_arrival_us: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                layers: Vec::new(),
                windows: Vec::new(),
                interarrival_ewma_us: 0.0,
            }),
        }
    }

    /// Fold one completed run into the rolling averages:
    /// `layer_names` / `codec_ratios` come straight from a pipeline
    /// report (parallel slices, one entry per layer), `frames` is how
    /// many frames that run covered. Also timestamps the arrival for
    /// the inter-arrival EWMA.
    pub fn observe(&self, layer_names: &[String], codec_ratios: &[f64],
                   frames: u64) {
        if frames == 0 {
            return;
        }
        let now_us = self.epoch.elapsed().as_micros() as u64;
        let prev = self
            .last_arrival_us
            .swap(now_us.saturating_add(1), Ordering::Relaxed);
        self.frames.fetch_add(frames, Ordering::Relaxed);

        let mut inner = self.inner.lock().unwrap();
        if prev != 0 {
            let gap = now_us.saturating_sub(prev - 1) as f64;
            inner.interarrival_ewma_us = if inner.interarrival_ewma_us
                == 0.0
            {
                gap
            } else {
                EWMA_ALPHA * gap
                    + (1.0 - EWMA_ALPHA) * inner.interarrival_ewma_us
            };
        }
        for (li, (name, &ratio)) in
            layer_names.iter().zip(codec_ratios).enumerate()
        {
            if inner.layers.len() <= li {
                inner.layers.push(LayerWorkload {
                    name: name.clone(),
                    density_ewma: ratio,
                    density_min: ratio,
                    density_max: ratio,
                    frames: 0,
                });
                inner.windows.push(VecDeque::new());
            }
            let win = &mut inner.windows[li];
            if win.len() == DENSITY_WINDOW {
                win.pop_front();
            }
            win.push_back(ratio);
            let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
            for &d in win.iter() {
                lo = lo.min(d);
                hi = hi.max(d);
            }
            let l = &mut inner.layers[li];
            l.density_min = lo;
            l.density_max = hi;
            if l.frames > 0 {
                l.density_ewma = EWMA_ALPHA * ratio
                    + (1.0 - EWMA_ALPHA) * l.density_ewma;
            } else {
                l.density_ewma = ratio;
            }
            l.frames += frames;
        }
    }

    /// Frames observed since construction.
    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    /// Snapshot every rolling statistic (cheap; clones the per-layer
    /// vector under the lock).
    pub fn snapshot(&self) -> WorkloadSnapshot {
        let inner = self.inner.lock().unwrap();
        let ia = inner.interarrival_ewma_us;
        WorkloadSnapshot {
            frames: self.frames(),
            interarrival_ewma_us: ia,
            rate_fps: if ia > 0.0 { 1e6 / ia } else { 0.0 },
            layers: inner.layers.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("l{i}")).collect()
    }

    #[test]
    fn folds_layer_densities_with_ewma() {
        let obs = WorkloadObserver::new();
        let ns = names(2);
        obs.observe(&ns, &[0.5, 0.1], 1);
        obs.observe(&ns, &[1.0, 0.1], 1);
        let s = obs.snapshot();
        assert_eq!(s.frames, 2);
        assert_eq!(s.layers.len(), 2);
        assert_eq!(s.layers[0].name, "l0");
        // First sample seeds the EWMA; second folds at alpha=0.2.
        assert!((s.layers[0].density_ewma - 0.6).abs() < 1e-9);
        assert!((s.layers[1].density_ewma - 0.1).abs() < 1e-9);
        assert_eq!(s.layers[0].frames, 2);
    }

    #[test]
    fn empty_and_zero_frame_observations_are_inert() {
        let obs = WorkloadObserver::new();
        obs.observe(&names(3), &[0.1, 0.2, 0.3], 0);
        let s = obs.snapshot();
        assert_eq!(s, WorkloadSnapshot::default());
        assert_eq!(s.rate_fps, 0.0);
    }

    #[test]
    fn interarrival_needs_two_arrivals() {
        let obs = WorkloadObserver::new();
        let ns = names(1);
        obs.observe(&ns, &[0.5], 1);
        assert_eq!(obs.snapshot().interarrival_ewma_us, 0.0);
        std::thread::sleep(std::time::Duration::from_millis(2));
        obs.observe(&ns, &[0.5], 1);
        let s = obs.snapshot();
        assert!(s.interarrival_ewma_us >= 1000.0,
                "slept 2ms between arrivals: {s:?}");
        assert!(s.rate_fps > 0.0);
    }

    /// Steady traffic: min == max == EWMA, spread ~0. Bimodal traffic
    /// alternating between two densities: the window brackets both
    /// modes while the EWMA settles in between — exactly the
    /// distinction the retune policy's bimodal guard needs.
    #[test]
    fn window_min_max_separates_steady_from_bimodal() {
        let steady = WorkloadObserver::new();
        let ns = names(1);
        for _ in 0..10 {
            steady.observe(&ns, &[0.4], 1);
        }
        let l = &steady.snapshot().layers[0];
        assert_eq!(l.density_min, 0.4);
        assert_eq!(l.density_max, 0.4);
        assert_eq!(l.density_spread(), 0.0);

        let bimodal = WorkloadObserver::new();
        for i in 0..10 {
            let d = if i % 2 == 0 { 0.1 } else { 0.7 };
            bimodal.observe(&ns, &[d], 1);
        }
        let l = &bimodal.snapshot().layers[0];
        assert_eq!(l.density_min, 0.1);
        assert_eq!(l.density_max, 0.7);
        assert!((l.density_spread() - 0.6).abs() < 1e-12);
        assert!(l.density_ewma > 0.1 && l.density_ewma < 0.7,
                "EWMA averages between the modes: {}", l.density_ewma);
    }

    /// Old extremes age out of the window: after DENSITY_WINDOW newer
    /// observations, an early outlier no longer sets min/max.
    #[test]
    fn window_min_max_forgets_old_extremes() {
        let obs = WorkloadObserver::new();
        let ns = names(1);
        obs.observe(&ns, &[0.95], 1); // outlier, should age out
        for _ in 0..DENSITY_WINDOW {
            obs.observe(&ns, &[0.2], 1);
        }
        let l = &obs.snapshot().layers[0];
        assert_eq!(l.density_min, 0.2);
        assert_eq!(l.density_max, 0.2, "outlier survived the window");
    }

    #[test]
    fn shared_across_threads() {
        use std::sync::Arc;
        let obs = Arc::new(WorkloadObserver::new());
        let ns = Arc::new(names(2));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let (o, n) = (obs.clone(), ns.clone());
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        o.observe(&n, &[0.25, 0.75], 1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let s = obs.snapshot();
        assert_eq!(s.frames, 200);
        assert!((s.layers[0].density_ewma - 0.25).abs() < 1e-9);
        assert!((s.layers[1].density_ewma - 0.75).abs() < 1e-9);
    }
}
