//! Allocation-bounded trace-span recorder with Chrome trace-event
//! JSON export.
//!
//! A [`TraceSink`] is shared behind an `Arc` by every instrumented
//! site in the stack (pipeline schedules, conv row bands, per-layer
//! stream workers, row-channel backpressure waits). Recording is a
//! single ring-slot store under a short mutex — no heap allocation
//! after construction, so the zero-allocation frame hot path stays
//! zero-allocation whether tracing is on or off (off is an `Option`
//! check at every site; `tests/alloc_budget.rs` pins the off case,
//! `tests/prop_telemetry.rs` pins that the on case changes no
//! architectural report field).
//!
//! The export format is the Chrome trace-event JSON array of `"ph":
//! "X"` complete events — load the file in Perfetto
//! (<https://ui.perfetto.dev>) or `chrome://tracing` to see the
//! streamed executor's per-layer overlap on a real timeline.

use std::fmt;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Default ring capacity (events) used by [`TraceSink::default`] and
/// the CLI `run --trace` path.
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

/// One recorded span: a Chrome "complete" event (`ph: "X"`).
///
/// Everything is `Copy` — names are `&'static str` and the two
/// optional arguments are numeric — so recording never allocates.
/// An argument slot with an empty key is unused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Category ("serial", "stream", "band", "backpressure", ...).
    pub cat: &'static str,
    /// Recording thread (stable small integer per host thread — the
    /// Perfetto track; per-layer workers land on distinct tracks,
    /// which is what makes their overlap visible).
    pub tid: u64,
    /// Span start, µs since the sink's construction.
    pub ts_us: u64,
    pub dur_us: u64,
    pub args: [(&'static str, u64); 2],
}

/// Fixed-capacity overwrite-oldest event ring.
struct Ring {
    buf: Vec<TraceEvent>,
    /// Overwrite cursor once `buf` has reached capacity.
    next: usize,
}

/// Shared span recorder: bounded memory no matter how long a run
/// traces, most recent events win. Construct once, share via `Arc`,
/// export with [`TraceSink::to_chrome_json`].
pub struct TraceSink {
    epoch: Instant,
    capacity: usize,
    ring: Mutex<Ring>,
    /// Events overwritten after the ring filled (kept out of the ring
    /// so the exported trace can say how much it is missing).
    dropped: AtomicU64,
}

/// Monotonically increasing id handed to each host thread on its
/// first recording — Chrome trace `tid`.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static THREAD_TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn thread_tid() -> u64 {
    THREAD_TID.with(|t| *t)
}

impl TraceSink {
    /// A sink holding at most `capacity` events (clamped to >= 1).
    /// The full ring is allocated up front; recording never grows it.
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            epoch: Instant::now(),
            capacity,
            ring: Mutex::new(Ring {
                buf: Vec::with_capacity(capacity),
                next: 0,
            }),
            dropped: AtomicU64::new(0),
        }
    }

    /// Microseconds since the sink was constructed — the span
    /// timestamp base. Take one at span entry, hand it back to
    /// [`TraceSink::record`] at exit.
    pub fn start(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record a span that started at `start_us` (from
    /// [`TraceSink::start`]) and ends now, on the calling thread's
    /// track. Unused argument slots carry an empty key.
    pub fn record(&self, name: &'static str, cat: &'static str,
                  start_us: u64, args: [(&'static str, u64); 2]) {
        let dur_us = self.start().saturating_sub(start_us);
        self.push(TraceEvent {
            name,
            cat,
            tid: thread_tid(),
            ts_us: start_us,
            dur_us,
            args,
        });
    }

    fn push(&self, ev: TraceEvent) {
        let mut ring = self.ring.lock().unwrap();
        if ring.buf.len() < self.capacity {
            ring.buf.push(ev);
        } else {
            let i = ring.next;
            ring.buf[i] = ev;
            ring.next = (i + 1) % self.capacity;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Events currently resident in the ring.
    pub fn len(&self) -> usize {
        self.ring.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ring capacity (events).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Events overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Snapshot the resident events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        let ring = self.ring.lock().unwrap();
        let mut out = Vec::with_capacity(ring.buf.len());
        out.extend_from_slice(&ring.buf[ring.next..]);
        out.extend_from_slice(&ring.buf[..ring.next]);
        out
    }

    /// Serialise the resident events as Chrome trace-event JSON —
    /// the `{"traceEvents": [...]}` object format Perfetto and
    /// `chrome://tracing` load directly. Span names and categories
    /// are `&'static str` identifiers and arguments are numeric, so
    /// no string escaping is required.
    pub fn to_chrome_json(&self) -> String {
        let evs = self.events();
        let mut s = String::with_capacity(evs.len() * 96 + 128);
        s.push_str("{\"traceEvents\":[");
        for (i, e) in evs.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\
                 \"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{},\"args\":{{",
                e.name, e.cat, e.ts_us, e.dur_us, e.tid
            );
            let mut first = true;
            for (k, v) in e.args.iter().filter(|(k, _)| !k.is_empty()) {
                if !first {
                    s.push(',');
                }
                let _ = write!(s, "\"{k}\":{v}");
                first = false;
            }
            s.push_str("}}");
        }
        let _ = write!(s, "],\"displayTimeUnit\":\"ms\",\
                           \"otherData\":{{\"dropped\":{}}}}}",
                       self.dropped());
        s
    }
}

impl Default for TraceSink {
    fn default() -> Self {
        Self::new(DEFAULT_TRACE_CAPACITY)
    }
}

// Manual impl: the mutex-held ring is an implementation detail, and
// `SessionBuilder` (which may hold an `Arc<TraceSink>`) derives Debug.
impl fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("TraceSink")
            .field("capacity", &self.capacity)
            .field("len", &self.len())
            .field("dropped", &self.dropped())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(sink: &TraceSink, name: &'static str, layer: u64) {
        let t0 = sink.start();
        sink.record(name, "test", t0, [("layer", layer), ("", 0)]);
    }

    #[test]
    fn records_and_exports_chrome_json() {
        let sink = TraceSink::new(16);
        ev(&sink, "alpha", 0);
        ev(&sink, "beta", 1);
        assert_eq!(sink.len(), 2);
        assert_eq!(sink.dropped(), 0);
        let json = sink.to_chrome_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"name\":\"alpha\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"layer\":1"));
        // Loadable by our own parser — structurally valid JSON.
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        let evs = parsed.get("traceEvents").and_then(|j| j.as_arr());
        assert_eq!(evs.map(|a| a.len()), Some(2));
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_dropped() {
        let sink = TraceSink::new(4);
        for i in 0..10u64 {
            ev(&sink, "e", i);
        }
        assert_eq!(sink.len(), 4);
        assert_eq!(sink.dropped(), 6);
        // Oldest-first snapshot holds the most recent 4 events.
        let layers: Vec<u64> =
            sink.events().iter().map(|e| e.args[0].1).collect();
        assert_eq!(layers, vec![6, 7, 8, 9]);
        assert!(sink.to_chrome_json().contains("\"dropped\":6"));
    }

    #[test]
    fn spans_carry_monotonic_timestamps_per_thread_tids() {
        let sink = std::sync::Arc::new(TraceSink::new(64));
        let t0 = sink.start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        sink.record("outer", "test", t0, [("", 0); 2]);
        let main_tid = sink.events()[0].tid;
        let s2 = sink.clone();
        std::thread::spawn(move || ev(&s2, "worker", 0))
            .join()
            .unwrap();
        let evs = sink.events();
        assert_eq!(evs.len(), 2);
        assert!(evs[0].dur_us >= 1000, "slept 2ms inside the span");
        assert_ne!(evs[1].tid, main_tid, "threads get distinct tracks");
    }

    #[test]
    fn empty_sink_exports_valid_json() {
        let sink = TraceSink::new(8);
        assert!(sink.is_empty());
        let parsed =
            crate::util::json::Json::parse(&sink.to_chrome_json())
                .unwrap();
        assert!(parsed.get("traceEvents").is_some());
    }
}
