//! Observability for the running stack: trace spans, a metrics
//! registry, and workload observers.
//!
//! The simulator's *architectural* instrumentation (cycle counts,
//! access counters, energy) describes what the modelled FPGA does;
//! this module observes what the *host* system is doing while it
//! runs:
//!
//! * [`trace`] — [`TraceSink`]: an allocation-bounded span recorder
//!   shared across the pipeline schedules, conv row bands, per-layer
//!   stream workers, and row-channel backpressure waits; exports
//!   Chrome trace-event JSON (`run --trace out.json`, view in
//!   Perfetto). Disabled tracing is a per-site `Option` check — the
//!   zero-allocation hot path and every architectural report stay
//!   bit-identical (pinned by `tests/prop_telemetry.rs`).
//! * [`registry`] — [`MetricsRegistry`]: named counters/gauges
//!   rendered as Prometheus text exposition, the payload of the
//!   server's `metrics` command.
//! * [`workload`] — [`WorkloadObserver`]: rolling per-layer spike
//!   density and frame inter-arrival EWMAs measured on the serving
//!   path — the inputs ROADMAP item 5's online DSE re-tuning
//!   consumes, surfaced via `Session::telemetry()` and `metrics`.

pub mod registry;
pub mod trace;
pub mod workload;

pub use registry::{Metric, MetricKind, MetricsRegistry, Sample};
pub use trace::{TraceEvent, TraceSink, DEFAULT_TRACE_CAPACITY};
pub use workload::{LayerWorkload, WorkloadObserver, WorkloadSnapshot};
