//! Named-metric registry with Prometheus text exposition.
//!
//! The server's `metrics` command (and anything else that wants a
//! scrape-able view of the stack) assembles a [`MetricsRegistry`]
//! from whatever live sources it holds — `ServerStats`,
//! `PoolMetrics`, queue depth, the [`workload
//! observer`](super::workload::WorkloadObserver) — and renders it as
//! the Prometheus text format: `# HELP` / `# TYPE` comment lines
//! followed by `name{label="v"} value` samples, terminated by a
//! `# EOF` line so line-oriented clients know where the reply ends.
//! The registry is a plain value built per scrape; the live counters
//! stay where they are.

use std::fmt::Write as _;

/// Prometheus metric kind (what `# TYPE` advertises).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
}

/// One sample of a metric: optional labels plus the value.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// One named metric family and its samples.
#[derive(Debug, Clone, PartialEq)]
pub struct Metric {
    pub name: String,
    pub help: String,
    pub kind: MetricKind,
    pub samples: Vec<Sample>,
}

impl Metric {
    /// Add an unlabelled sample.
    pub fn sample(&mut self, value: f64) -> &mut Self {
        self.samples.push(Sample { labels: Vec::new(), value });
        self
    }

    /// Add a labelled sample.
    pub fn sample_with(&mut self, labels: &[(&str, &str)], value: f64)
                       -> &mut Self {
        self.samples.push(Sample {
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            value,
        });
        self
    }
}

/// An ordered collection of metric families, rendered in insertion
/// order (stable scrape output).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    metrics: Vec<Metric>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a counter family; sample it via the returned handle.
    pub fn counter(&mut self, name: &str, help: &str) -> &mut Metric {
        self.family(name, help, MetricKind::Counter)
    }

    /// Register a gauge family; sample it via the returned handle.
    pub fn gauge(&mut self, name: &str, help: &str) -> &mut Metric {
        self.family(name, help, MetricKind::Gauge)
    }

    fn family(&mut self, name: &str, help: &str, kind: MetricKind)
              -> &mut Metric {
        self.metrics.push(Metric {
            name: name.to_string(),
            help: help.to_string(),
            kind,
            samples: Vec::new(),
        });
        self.metrics.last_mut().unwrap()
    }

    pub fn metrics(&self) -> &[Metric] {
        &self.metrics
    }

    /// Render the Prometheus text exposition, `# EOF`-terminated.
    /// Families with no samples are skipped (a source that was not
    /// wired simply does not appear).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for m in &self.metrics {
            if m.samples.is_empty() {
                continue;
            }
            let kind = match m.kind {
                MetricKind::Counter => "counter",
                MetricKind::Gauge => "gauge",
            };
            let _ = writeln!(out, "# HELP {} {}", m.name, m.help);
            let _ = writeln!(out, "# TYPE {} {}", m.name, kind);
            for s in &m.samples {
                out.push_str(&m.name);
                if !s.labels.is_empty() {
                    out.push('{');
                    for (i, (k, v)) in s.labels.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        let _ = write!(out, "{k}=\"{}\"",
                                       escape_label(v));
                    }
                    out.push('}');
                }
                let _ = writeln!(out, " {}", fmt_value(s.value));
            }
        }
        out.push_str("# EOF\n");
        out
    }
}

/// Prometheus label-value escaping: backslash, quote, newline.
fn escape_label(v: &str) -> String {
    let mut s = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => s.push_str("\\\\"),
            '"' => s.push_str("\\\""),
            '\n' => s.push_str("\\n"),
            c => s.push(c),
        }
    }
    s
}

/// Integers print without a trailing `.0`; everything else as plain
/// decimal (the util::json::Json display convention).
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_help_type_samples_and_eof() {
        let mut reg = MetricsRegistry::new();
        reg.counter("sti_requests_total", "Requests served.")
            .sample(42.0);
        reg.gauge("sti_layer_spike_density", "Observed density.")
            .sample_with(&[("layer", "conv0")], 0.25)
            .sample_with(&[("layer", "fc")], 0.5);
        let text = reg.render();
        assert!(text.contains(
            "# HELP sti_requests_total Requests served.\n"));
        assert!(text.contains("# TYPE sti_requests_total counter\n"));
        assert!(text.contains("\nsti_requests_total 42\n"));
        assert!(text.contains(
            "sti_layer_spike_density{layer=\"conv0\"} 0.25\n"));
        assert!(text.contains(
            "sti_layer_spike_density{layer=\"fc\"} 0.5\n"));
        assert!(text.ends_with("# EOF\n"));
    }

    #[test]
    fn empty_families_are_skipped_and_labels_escaped() {
        let mut reg = MetricsRegistry::new();
        reg.counter("sti_never_sampled", "No samples.");
        reg.gauge("g", "h").sample_with(&[("l", "a\"b\\c")], 1.0);
        let text = reg.render();
        assert!(!text.contains("sti_never_sampled"));
        assert!(text.contains("g{l=\"a\\\"b\\\\c\"} 1\n"));
        assert_eq!(MetricsRegistry::new().render(), "# EOF\n");
    }

    #[test]
    fn multi_label_samples_and_float_formatting() {
        let mut reg = MetricsRegistry::new();
        reg.gauge("lat", "Latency.")
            .sample_with(&[("quantile", "0.5"), ("unit", "us")], 12.5);
        let text = reg.render();
        assert!(text.contains(
            "lat{quantile=\"0.5\",unit=\"us\"} 12.5\n"));
    }
}
