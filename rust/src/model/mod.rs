//! Artifact loader: `net.json` + `weights.bin` emitted by
//! `python/compile/aot.py`.
//!
//! Layout contract (kept in sync with aot.py):
//! * `net.json` — network description (`arch::NetworkSpec::from_json`)
//!   plus a `tensors` manifest: name, per-layer index, kind
//!   (`int8`/`f32`), shape, quant scale, byte offset and length into
//!   `weights.bin`.
//! * `weights.bin` — concatenated tensor bytes; int8 raw, f32 LE.
//! * Conv weights are pre-transposed by aot.py to the engine layout
//!   `[co][ci][tap]` (depthwise `[c][1][tap]`, pointwise `[co][ci][1]`);
//!   FC weights to `[n_in][n_out]`.
//! * `encoder.hlo.txt` / `model.hlo.txt` — the AOT graphs for the
//!   runtime (spike encoding; full-net logits reference).

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::arch::{Layer, NetworkSpec};
use crate::sim::conv_engine::ConvWeights;
use crate::sim::engine::LayerWeights;
use crate::util::json::Json;

/// One tensor record from the manifest.
#[derive(Debug, Clone)]
pub struct TensorRec {
    pub layer: usize,
    pub name: String,
    pub kind: String,
    pub shape: Vec<usize>,
    pub scale: f32,
    pub offset: usize,
    pub len: usize,
}

/// A fully-loaded model artifact.
pub struct Artifact {
    pub dir: PathBuf,
    pub net: NetworkSpec,
    pub vth: f32,
    pub timesteps: usize,
    pub tensors: Vec<TensorRec>,
    blob: Vec<u8>,
}

impl Artifact {
    pub fn load(dir: &Path) -> Result<Self> {
        let net_path = dir.join("net.json");
        let txt = std::fs::read_to_string(&net_path)
            .with_context(|| format!("reading {net_path:?}"))?;
        let j = Json::parse(&txt)
            .with_context(|| format!("parsing {net_path:?}"))?;
        let net = NetworkSpec::from_json(&j)?;
        let vth =
            j.get("vth").and_then(|v| v.as_f64()).unwrap_or(1.0) as f32;
        let timesteps =
            j.get("timesteps").and_then(|v| v.as_usize()).unwrap_or(1);

        let mut tensors = Vec::new();
        if let Some(arr) = j.get("tensors").and_then(|v| v.as_arr()) {
            for t in arr {
                tensors.push(TensorRec {
                    layer: t.get("layer").and_then(|v| v.as_usize())
                        .context("tensor.layer")?,
                    name: t.get("name").and_then(|v| v.as_str())
                        .context("tensor.name")?.to_string(),
                    kind: t.get("kind").and_then(|v| v.as_str())
                        .context("tensor.kind")?.to_string(),
                    shape: t.get("shape").and_then(|v| v.as_arr())
                        .map(|a| a.iter()
                             .filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default(),
                    scale: t.get("scale").and_then(|v| v.as_f64())
                        .unwrap_or(1.0) as f32,
                    offset: t.get("offset").and_then(|v| v.as_usize())
                        .context("tensor.offset")?,
                    len: t.get("len").and_then(|v| v.as_usize())
                        .context("tensor.len")?,
                });
            }
        }

        let blob = if tensors.is_empty() {
            Vec::new()
        } else {
            std::fs::read(dir.join("weights.bin"))
                .with_context(|| format!("reading {dir:?}/weights.bin"))?
        };

        Ok(Self {
            dir: dir.to_path_buf(),
            net,
            vth,
            timesteps,
            tensors,
            blob,
        })
    }

    fn tensor(&self, layer: usize, name: &str) -> Result<&TensorRec> {
        self.tensors
            .iter()
            .find(|t| t.layer == layer && t.name == name)
            .with_context(|| format!("tensor layer={layer} name={name}"))
    }

    pub fn int8(&self, rec: &TensorRec) -> Result<Vec<i8>> {
        anyhow::ensure!(rec.kind == "int8", "{} is {}", rec.name, rec.kind);
        let bytes = self
            .blob
            .get(rec.offset..rec.offset + rec.len)
            .context("tensor out of blob bounds")?;
        Ok(bytes.iter().map(|&b| b as i8).collect())
    }

    pub fn f32(&self, rec: &TensorRec) -> Result<Vec<f32>> {
        anyhow::ensure!(rec.kind == "f32", "{} is {}", rec.name, rec.kind);
        let bytes = self
            .blob
            .get(rec.offset..rec.offset + rec.len)
            .context("tensor out of blob bounds")?;
        anyhow::ensure!(bytes.len() % 4 == 0);
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Build per-layer engine weight sources from the manifest —
    /// what `sti_snn::session::Weights::Artifact` resolves to.
    pub fn layer_weights(&self) -> Result<Vec<LayerWeights>> {
        let mut out = Vec::new();
        for (li, layer) in self.net.layers.iter().enumerate() {
            match layer {
                Layer::Conv(c) if !c.encoder => {
                    let wrec = self.tensor(li, "w")?;
                    let brec = self.tensor(li, "b")?;
                    let w = ConvWeights::new(
                        c,
                        self.int8(wrec)?,
                        wrec.scale,
                        self.f32(brec)?,
                        self.vth,
                    );
                    out.push(LayerWeights::Conv(w));
                }
                Layer::Fc { .. } => {
                    let wrec = self.tensor(li, "w")?;
                    let brec = self.tensor(li, "b")?;
                    out.push(LayerWeights::Fc {
                        weights: self.int8(wrec)?,
                        scale: wrec.scale,
                        bias: self.f32(brec)?,
                    });
                }
                _ => {}
            }
        }
        Ok(out)
    }

    pub fn encoder_hlo(&self) -> PathBuf {
        self.dir.join("encoder.hlo.txt")
    }

    pub fn model_hlo(&self) -> PathBuf {
        self.dir.join("model.hlo.txt")
    }

    /// Post-encoder spike-frame shape (the pipeline's input).
    pub fn encoder_out_shape(&self) -> (usize, usize, usize) {
        for l in &self.net.layers {
            if let Layer::Conv(c) = l {
                if c.encoder {
                    return (c.out_h(), c.out_w(), c.co);
                }
            }
        }
        self.net.input
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Round-trip a synthetic artifact through the loader.
    #[test]
    fn load_synthetic_artifact() {
        let dir = std::env::temp_dir().join("sti_snn_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();

        // conv layer 1 (non-encoder): 2 -> 2 channels, 3x3.
        // taps: [co][ci][9] = 2*2*9 = 36 int8 bytes at offset 0.
        // bias: 2 f32 = 8 bytes at offset 36.
        // fc: 8 -> 2, w 16 bytes at 44, b 8 bytes at 60.
        let mut blob: Vec<u8> = Vec::new();
        blob.extend((0..36u8).map(|i| i));          // conv w
        blob.extend(0.5f32.to_le_bytes());          // conv b[0]
        blob.extend((-0.5f32).to_le_bytes());       // conv b[1]
        blob.extend((0..16u8).map(|i| i));          // fc w
        blob.extend(1.0f32.to_le_bytes());
        blob.extend(2.0f32.to_le_bytes());
        std::fs::write(dir.join("weights.bin"), &blob).unwrap();

        let net_json = r#"{
          "name": "tiny", "input": [4, 4, 1], "vth": 1.0, "timesteps": 1,
          "layers": [
            {"kind":"conv","in_h":4,"in_w":4,"in_c":1,"co":2,"k":3,
             "pad":1,"encoder":true},
            {"kind":"conv","in_h":4,"in_w":4,"in_c":2,"co":2,"k":3,
             "pad":1,"encoder":false},
            {"kind":"pool","in_h":4,"in_w":4,"in_c":2},
            {"kind":"fc","in_h":2,"in_w":2,"in_c":2,"out":2}
          ],
          "tensors": [
            {"layer":1,"name":"w","kind":"int8","shape":[2,2,9],
             "scale":0.01,"offset":0,"len":36},
            {"layer":1,"name":"b","kind":"f32","shape":[2],
             "scale":1.0,"offset":36,"len":8},
            {"layer":3,"name":"w","kind":"int8","shape":[8,2],
             "scale":0.02,"offset":44,"len":16},
            {"layer":3,"name":"b","kind":"f32","shape":[2],
             "scale":1.0,"offset":60,"len":8}
          ]
        }"#;
        std::fs::write(dir.join("net.json"), net_json).unwrap();

        let art = Artifact::load(&dir).unwrap();
        assert_eq!(art.net.name, "tiny");
        assert_eq!(art.encoder_out_shape(), (4, 4, 2));
        let params = art.layer_weights().unwrap();
        assert_eq!(params.len(), 2);
        match &params[0] {
            LayerWeights::Conv(w) => {
                assert!((w.scale - 0.01).abs() < 1e-9);
                assert_eq!(w.bias, vec![0.5, -0.5]);
            }
            _ => panic!("expected conv"),
        }
        match &params[1] {
            LayerWeights::Fc { weights, scale, bias } => {
                assert_eq!(weights.len(), 16);
                assert!((scale - 0.02).abs() < 1e-9);
                assert_eq!(bias, &vec![1.0, 2.0]);
            }
            _ => panic!("expected fc"),
        }
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Artifact::load(Path::new("/nonexistent/xyz")).is_err());
    }
}
