//! Frame batching / request queue for the serving path (host side of
//! paper Fig. 10).
//!
//! The TCP server and the replica pool enqueue work items; consumer
//! threads drain them in batches (larger batches amortise the pipeline
//! fill, Eq. 11 — and, on the streamed executor, keep several frames
//! in flight across the per-layer workers of one `Pipeline::run`
//! call). The queue is generic over the item type so the same
//! structure backs both the simulator-facing [`Request`] queue and the
//! server's in-flight job queue. Multiple consumers may drain one
//! queue concurrently — that is exactly how the replica pool shares
//! work across pipelines. Plain std sync — tokio is not vendored in
//! this environment.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::codec::SpikeFrame;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub frame: SpikeFrame,
    pub enqueued_at: Instant,
}

/// Thread-safe batching queue with a max-batch / max-wait policy and
/// an optional depth bound (the serving backpressure primitive:
/// [`Batcher::try_push`] refuses work past `capacity` so callers can
/// shed explicitly instead of queueing unboundedly).
pub struct Batcher<T> {
    inner: Mutex<VecDeque<T>>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Depth bound enforced by [`Batcher::try_push`] (0 = unbounded).
    pub capacity: usize,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self::with_capacity(max_batch, max_wait, 0)
    }

    /// A queue that [`Batcher::try_push`] bounds at `capacity` items
    /// (0 = unbounded; [`Batcher::push`] always accepts either way).
    pub fn with_capacity(max_batch: usize, max_wait: Duration,
                         capacity: usize) -> Self {
        assert!(max_batch > 0);
        Self {
            inner: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            max_batch,
            max_wait,
            capacity,
        }
    }

    pub fn push(&self, item: T) {
        self.inner.lock().unwrap().push_back(item);
        self.cv.notify_one();
    }

    /// Push unless the queue already holds `capacity` items; the item
    /// comes back in `Err` so the caller can shed it explicitly.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut q = self.inner.lock().unwrap();
        if self.capacity > 0 && q.len() >= self.capacity {
            return Err(item);
        }
        q.push_back(item);
        drop(q);
        self.cv.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take the next batch: waits up to `max_wait` for the first
    /// item, then drains up to `max_batch`. Returns an empty vec on
    /// timeout with nothing queued.
    pub fn next_batch(&self) -> Vec<T> {
        let mut q = self.inner.lock().unwrap();
        if q.is_empty() {
            let (guard, _timeout) = self
                .cv
                .wait_timeout_while(q, self.max_wait, |q| q.is_empty())
                .unwrap();
            q = guard;
        }
        let n = q.len().min(self.max_batch);
        q.drain(..n).collect()
    }

    /// Non-blocking variant used by the simulator-driven loop.
    pub fn try_batch(&self) -> Vec<T> {
        let mut q = self.inner.lock().unwrap();
        let n = q.len().min(self.max_batch);
        q.drain(..n).collect()
    }

    /// Drain everything immediately (shutdown path: reply with errors).
    pub fn drain_all(&self) -> Vec<T> {
        let mut q = self.inner.lock().unwrap();
        q.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request {
            id,
            frame: SpikeFrame::zeros(4, 4, 2),
            enqueued_at: Instant::now(),
        }
    }

    #[test]
    fn batches_respect_max_batch() {
        let b = Batcher::new(3, Duration::from_millis(10));
        for i in 0..7 {
            b.push(req(i));
        }
        assert_eq!(b.try_batch().len(), 3);
        assert_eq!(b.try_batch().len(), 3);
        assert_eq!(b.try_batch().len(), 1);
        assert!(b.try_batch().is_empty());
    }

    #[test]
    fn fifo_order_preserved() {
        let b = Batcher::new(8, Duration::from_millis(10));
        for i in 0..5 {
            b.push(req(i));
        }
        let ids: Vec<u64> = b.try_batch().iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn next_batch_times_out_empty() {
        let b: Batcher<Request> = Batcher::new(4, Duration::from_millis(5));
        let batch = b.next_batch();
        assert!(batch.is_empty());
    }

    #[test]
    fn cross_thread_wakeup() {
        let b = Arc::new(Batcher::new(4, Duration::from_secs(2)));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.push(req(42));
        let got = h.join().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 42);
    }

    #[test]
    fn generic_items_and_drain_all() {
        let b: Batcher<u32> = Batcher::new(2, Duration::from_millis(1));
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.try_batch(), vec![0, 1]);
        assert_eq!(b.drain_all(), vec![2, 3, 4]);
        assert!(b.is_empty());
    }

    /// Bounded queues refuse (and hand back) work past capacity; a
    /// drain frees slots again.
    #[test]
    fn try_push_sheds_past_capacity() {
        let b: Batcher<u32> =
            Batcher::with_capacity(4, Duration::from_millis(1), 2);
        assert!(b.try_push(1).is_ok());
        assert!(b.try_push(2).is_ok());
        assert_eq!(b.try_push(3), Err(3), "full queue returns the item");
        // push() stays unbounded for callers without a shed path.
        b.push(4);
        assert_eq!(b.len(), 3);
        assert_eq!(b.try_batch(), vec![1, 2, 4]);
        assert!(b.try_push(5).is_ok());
        // capacity 0 = unbounded try_push.
        let u: Batcher<u32> = Batcher::new(4, Duration::from_millis(1));
        for i in 0..100 {
            assert!(u.try_push(i).is_ok());
        }
    }

    /// Two consumers on one queue see disjoint items covering the whole
    /// input — the replica-pool sharing contract.
    #[test]
    fn multiple_consumers_partition_the_queue() {
        let b = Arc::new(Batcher::new(4, Duration::from_millis(5)));
        for i in 0..64u64 {
            b.push(req(i));
        }
        let mut handles = Vec::new();
        for _ in 0..2 {
            let q = b.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    let batch = q.try_batch();
                    if batch.is_empty() {
                        break;
                    }
                    got.extend(batch.into_iter().map(|r| r.id));
                }
                got
            }));
        }
        let mut all: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..64).collect::<Vec<_>>());
    }
}
