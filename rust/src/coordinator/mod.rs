//! Streaming coordinator: the layer-wise pipelined architecture
//! (paper SectionIV-E) built from per-layer engines.
//!
//! * [`pipeline`] — composes one boxed
//!   [`LayerEngine`](crate::sim::engine::LayerEngine) per layer,
//!   connects them with inter-layer FIFOs + the spike-event codec,
//!   runs frames through the pipeline with Eq. (10)/(11) cycle
//!   accounting, and aggregates the energy / traffic / resource
//!   reports that the Table IV / Fig. 11 / Fig. 12 experiments
//!   consume. Construct pipelines through the
//!   `sti_snn::session::Session` facade.
//! * [`scheduler`] — the output-channel parallel-factor optimiser:
//!   given a PE budget, pick per-layer factors that minimise the
//!   pipeline interval (the latency model drives the search).
//! * [`batch`] — generic batching work queue for the serving path.
//! * [`replica`] — N-pipeline replica pool draining one shared queue
//!   (multi-core parallel serving; per-replica metrics in
//!   `crate::metrics`).

pub mod batch;
pub mod pipeline;
pub mod replica;
pub mod scheduler;

pub use batch::{Batcher, Request};
pub use pipeline::{Pipeline, PipelineConfig, PipelineReport};
pub use replica::{PoolResult, ReplicaPool};
pub use scheduler::{optimize_factors, ScheduleChoice};
