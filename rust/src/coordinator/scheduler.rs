//! Output-channel parallel-factor optimiser (paper SectionIV-E.2).
//!
//! The pipeline interval is the slowest conv layer (Eq. 11); spending
//! PE lanes on that layer divides its `Co` walk.  The paper picks
//! factors by hand ((4,2) for SCNN3, (4,4,2,1) for SCNN5); this module
//! automates the choice: greedy steepest-descent on the latency model —
//! repeatedly double the bottleneck layer's factor while the PE budget
//! allows, which is optimal for this objective because layer latencies
//! are independent and monotone in their own factor.

use crate::arch::{Layer, NetworkSpec};
use crate::dataflow::{conv_latency, ConvLatencyParams};

/// A chosen schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleChoice {
    pub factors: Vec<usize>,
    pub pes: usize,
    /// Pipeline interval (cycles) under the latency model.
    pub t_max: u64,
    /// Interval before optimisation (all factors 1).
    pub t_max_base: u64,
}

impl ScheduleChoice {
    pub fn speedup(&self) -> f64 {
        self.t_max_base as f64 / self.t_max as f64
    }

    /// Steady-state frames/s of one pipeline at this schedule (Eq. 11,
    /// N -> inf) for a given clock.
    pub fn fps(&self, clk_hz: f64) -> f64 {
        clk_hz / self.t_max as f64
    }
}

/// Split a total PE budget across `replicas` identical pipeline copies
/// (the serving pool of `coordinator::replica`) and schedule each copy
/// with its share. Returns the per-replica choice plus the aggregate
/// steady-state throughput multiplier: replicas trade per-frame latency
/// (fewer lanes per copy) for request throughput (more copies).
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicatedSchedule {
    pub replicas: usize,
    pub per_replica: ScheduleChoice,
    /// Total PEs across all replicas.
    pub pes_total: usize,
}

impl ReplicatedSchedule {
    /// Aggregate frames/s of the whole pool at a given clock.
    pub fn pool_fps(&self, clk_hz: f64) -> f64 {
        self.replicas as f64 * self.per_replica.fps(clk_hz)
    }
}

/// Schedule `replicas` identical copies under one total PE budget.
pub fn optimize_replicated(net: &NetworkSpec, pe_budget: usize,
                           replicas: usize, timing: &ConvLatencyParams)
                           -> ReplicatedSchedule {
    let replicas = replicas.max(1);
    let per_replica =
        optimize_factors(net, pe_budget / replicas, timing);
    ReplicatedSchedule {
        replicas,
        pes_total: per_replica.pes * replicas,
        per_replica,
    }
}

/// Choose per-conv-layer factors under a total-PE budget.
///
/// Factors are powers of two (the RTL's lane replication), capped at
/// each layer's `Co`.
pub fn optimize_factors(net: &NetworkSpec, pe_budget: usize,
                        timing: &ConvLatencyParams) -> ScheduleChoice {
    let convs = net.accel_convs();
    assert!(!convs.is_empty(), "network has no accelerated conv layers");
    let mut factors = vec![1usize; convs.len()];

    let latency = |factors: &[usize]| -> Vec<u64> {
        convs
            .iter()
            .zip(factors)
            .map(|(c, &f)| {
                let mut l = (*c).clone();
                l.parallel = f;
                conv_latency(&l, timing)
            })
            .collect()
    };
    let pes = |factors: &[usize]| -> usize {
        convs
            .iter()
            .zip(factors)
            .map(|(c, &f)| c.kh * c.kw * f)
            .sum()
    };

    let base_lat = latency(&factors);
    let t_max_base = *base_lat.iter().max().unwrap();

    loop {
        let lat = latency(&factors);
        // Find the bottleneck layer that can still be doubled in budget.
        let mut order: Vec<usize> = (0..factors.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(lat[i]));
        let mut improved = false;
        for &i in &order {
            let c = convs[i];
            if factors[i] * 2 > c.co {
                continue; // no more channels to parallelise
            }
            let mut trial = factors.clone();
            trial[i] *= 2;
            if pes(&trial) > pe_budget {
                continue;
            }
            // Only useful if it lowers the global max.
            let new_lat = latency(&trial);
            if new_lat.iter().max() < lat.iter().max() {
                factors = trial;
                improved = true;
                break;
            }
        }
        if !improved {
            break;
        }
    }

    let final_lat = latency(&factors);
    ScheduleChoice {
        pes: pes(&factors),
        t_max: *final_lat.iter().max().unwrap(),
        t_max_base,
        factors,
    }
}

/// Apply a schedule to a network spec.
pub fn apply(net: NetworkSpec, choice: &ScheduleChoice) -> NetworkSpec {
    net.with_parallel_factors(&choice.factors)
}

/// Sweep PE budgets, reporting the latency/PE trade-off curve (the
/// flexibility argument of SectionV-C).
pub fn budget_sweep(net: &NetworkSpec, budgets: &[usize],
                    timing: &ConvLatencyParams) -> Vec<ScheduleChoice> {
    budgets
        .iter()
        .map(|&b| optimize_factors(net, b, timing))
        .collect()
}

fn _assert_layer_types(net: &NetworkSpec) {
    for l in &net.layers {
        match l {
            Layer::Conv(_) | Layer::Pool { .. } | Layer::Fc { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{scnn3, scnn5};

    #[test]
    fn scnn5_budget_recovers_paper_profile() {
        // With the paper's 99-PE budget the optimiser should find a
        // schedule at least as good as the hand-picked (4,4,2,1).
        let net = scnn5();
        let timing = ConvLatencyParams::optimized();
        let choice = optimize_factors(&net, 99, &timing);
        assert!(choice.pes <= 99);
        let hand = crate::dataflow::pipeline_latency(
            &scnn5().with_parallel_factors(&[4, 4, 2, 1]), &timing, 1);
        assert!(choice.t_max <= hand.t_max,
                "optimizer {} vs hand {}", choice.t_max, hand.t_max);
        assert!(choice.speedup() > 3.0);
    }

    #[test]
    fn scnn3_budget_recovers_paper_profile() {
        let choice = optimize_factors(&scnn3(), 54,
                                      &ConvLatencyParams::optimized());
        assert!(choice.pes <= 54);
        // Paper's (4,2) gives 54 PEs; ours must do at least as well.
        let hand = crate::dataflow::pipeline_latency(
            &scnn3().with_parallel_factors(&[4, 2]),
            &ConvLatencyParams::optimized(), 1);
        assert!(choice.t_max <= hand.t_max);
    }

    #[test]
    fn minimal_budget_gives_unit_factors() {
        let net = scnn5();
        // 4 conv layers x 9 PEs = 36 minimum.
        let choice = optimize_factors(&net, 36,
                                      &ConvLatencyParams::optimized());
        assert_eq!(choice.factors, vec![1, 1, 1, 1]);
        assert_eq!(choice.speedup(), 1.0);
    }

    #[test]
    fn factors_never_exceed_co() {
        let net = scnn3();
        let choice = optimize_factors(&net, 100_000,
                                      &ConvLatencyParams::optimized());
        for (c, f) in net.accel_convs().iter().zip(&choice.factors) {
            assert!(*f <= c.co);
        }
    }

    /// Once output-channel parallelism saturates (factors capped at
    /// Co), one pipeline cannot absorb more PEs — but replicas can:
    /// the pool turns the leftover budget into request throughput.
    #[test]
    fn replicated_schedule_scales_past_the_co_cap() {
        let net = scnn3(); // conv Co = 32 caps factors at 32
        let timing = ConvLatencyParams::optimized();
        let budget = 4 * 64 * 9; // 4x the max useful single budget
        let single = optimize_replicated(&net, budget, 1, &timing);
        let quad = optimize_replicated(&net, budget, 4, &timing);
        assert_eq!(quad.replicas, 4);
        assert!(quad.pes_total <= budget);
        // Saturated: every replica reaches the same (capped) schedule.
        assert_eq!(quad.per_replica.t_max, single.per_replica.t_max);
        // So the pool's aggregate throughput is ~4x the single pipe.
        let ratio = quad.pool_fps(200e6) / single.pool_fps(200e6);
        assert!(ratio > 3.9, "pool scaled only {ratio}x");
    }

    #[test]
    fn monotone_in_budget() {
        let net = scnn5();
        let timing = ConvLatencyParams::optimized();
        let sweep = budget_sweep(&net, &[36, 54, 99, 198, 396], &timing);
        for w in sweep.windows(2) {
            assert!(w[1].t_max <= w[0].t_max,
                    "latency must not increase with budget");
        }
    }
}
