//! Output-channel parallel-factor scheduler (paper SectionIV-E.2) —
//! now a thin facade over the `dse` evaluator.
//!
//! The pipeline interval is the slowest conv layer (Eq. 11); spending
//! PE lanes on that layer divides its `Co` walk. The paper picks
//! factors by hand ((4,2) for SCNN3, (4,4,2,1) for SCNN5); the greedy
//! optimiser automating that choice lives in
//! [`crate::dse::evaluate`] together with the rest of the cost math —
//! this module keeps the historical entry points (and their tests) for
//! existing callers.

use crate::arch::NetworkSpec;
use crate::dataflow::ConvLatencyParams;
use crate::dse::evaluate;

pub use crate::dse::evaluate::{ReplicatedSchedule, ScheduleChoice};

/// Choose per-conv-layer factors under a total-PE budget (delegates to
/// [`crate::dse::evaluate::optimize_factors`]).
pub fn optimize_factors(net: &NetworkSpec, pe_budget: usize,
                        timing: &ConvLatencyParams) -> ScheduleChoice {
    evaluate::optimize_factors(net, pe_budget, timing)
}

/// Schedule `replicas` identical copies under one total PE budget.
pub fn optimize_replicated(net: &NetworkSpec, pe_budget: usize,
                           replicas: usize, timing: &ConvLatencyParams)
                           -> ReplicatedSchedule {
    evaluate::optimize_replicated(net, pe_budget, replicas, timing)
}

/// Sweep PE budgets, reporting the latency/PE trade-off curve (the
/// flexibility argument of SectionV-C).
pub fn budget_sweep(net: &NetworkSpec, budgets: &[usize],
                    timing: &ConvLatencyParams) -> Vec<ScheduleChoice> {
    evaluate::budget_sweep(net, budgets, timing)
}

/// Apply a schedule to a network spec. Errors if the schedule's
/// factors do not validate against the spec (e.g. a schedule computed
/// for a different network).
pub fn apply(net: NetworkSpec, choice: &ScheduleChoice)
             -> anyhow::Result<NetworkSpec> {
    net.try_with_parallel_factors(&choice.factors)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{scnn3, scnn5};

    #[test]
    fn scnn5_budget_recovers_paper_profile() {
        // With the paper's 99-PE budget the optimiser should find a
        // schedule at least as good as the hand-picked (4,4,2,1).
        let net = scnn5();
        let timing = ConvLatencyParams::optimized();
        let choice = optimize_factors(&net, 99, &timing);
        assert!(choice.pes <= 99);
        let hand = crate::dataflow::pipeline_latency(
            &scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap(), &timing, 1);
        assert!(choice.t_max <= hand.t_max,
                "optimizer {} vs hand {}", choice.t_max, hand.t_max);
        assert!(choice.speedup() > 3.0);
    }

    #[test]
    fn scnn3_budget_recovers_paper_profile() {
        let choice = optimize_factors(&scnn3(), 54,
                                      &ConvLatencyParams::optimized());
        assert!(choice.pes <= 54);
        // Paper's (4,2) gives 54 PEs; ours must do at least as well.
        let hand = crate::dataflow::pipeline_latency(
            &scnn3().try_with_parallel_factors(&[4, 2]).unwrap(),
            &ConvLatencyParams::optimized(), 1);
        assert!(choice.t_max <= hand.t_max);
    }

    #[test]
    fn minimal_budget_gives_unit_factors() {
        let net = scnn5();
        // 4 conv layers x 9 PEs = 36 minimum.
        let choice = optimize_factors(&net, 36,
                                      &ConvLatencyParams::optimized());
        assert_eq!(choice.factors, vec![1, 1, 1, 1]);
        assert_eq!(choice.speedup(), 1.0);
    }

    #[test]
    fn factors_never_exceed_co() {
        let net = scnn3();
        let choice = optimize_factors(&net, 100_000,
                                      &ConvLatencyParams::optimized());
        for (c, f) in net.accel_convs().iter().zip(&choice.factors) {
            assert!(*f <= c.co);
        }
    }

    /// Once output-channel parallelism saturates (factors capped at
    /// Co), one pipeline cannot absorb more PEs — but replicas can:
    /// the pool turns the leftover budget into request throughput.
    #[test]
    fn replicated_schedule_scales_past_the_co_cap() {
        let net = scnn3(); // conv Co = 32 caps factors at 32
        let timing = ConvLatencyParams::optimized();
        let budget = 4 * 64 * 9; // 4x the max useful single budget
        let single = optimize_replicated(&net, budget, 1, &timing);
        let quad = optimize_replicated(&net, budget, 4, &timing);
        assert_eq!(quad.replicas, 4);
        assert!(quad.pes_total <= budget);
        // Saturated: every replica reaches the same (capped) schedule.
        assert_eq!(quad.per_replica.t_max, single.per_replica.t_max);
        // So the pool's aggregate throughput is ~4x the single pipe.
        let ratio = quad.pool_fps(200e6) / single.pool_fps(200e6);
        assert!(ratio > 3.9, "pool scaled only {ratio}x");
    }

    #[test]
    fn monotone_in_budget() {
        let net = scnn5();
        let timing = ConvLatencyParams::optimized();
        let sweep = budget_sweep(&net, &[36, 54, 99, 198, 396], &timing);
        for w in sweep.windows(2) {
            assert!(w[1].t_max <= w[0].t_max,
                    "latency must not increase with budget");
        }
    }

    /// Wrapper parity: `apply` produces the same network as assigning
    /// the schedule's factors directly, and the factors validate.
    #[test]
    fn apply_matches_direct_assignment() {
        let net = scnn5();
        let timing = ConvLatencyParams::optimized();
        let choice = optimize_factors(&net, 99, &timing);
        let a = apply(net.clone(), &choice).unwrap();
        let b = net.clone().try_with_parallel_factors(&choice.factors)
            .expect("scheduler factors are always valid");
        assert_eq!(a, b);
    }
}
