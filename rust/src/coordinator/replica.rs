//! Multi-pipeline parallel serving: N accelerator-pipeline replicas
//! draining one shared work queue, with zero-downtime generation
//! swaps.
//!
//! The paper's system is one physical accelerator; the reproduction's
//! north star is a *production* simulator that saturates the host, so
//! the coordinator generalises from one pipeline to a configurable
//! pool of replicas. Each replica owns a full [`Pipeline`] (its own
//! engines and weight copies — no sharing, no locks on the hot path)
//! and a worker thread that drains the shared [`Batcher`] queue.
//! Throughput scales with host cores while per-request results stay
//! identical to a single pipeline (pinned by tests — the pipeline is
//! stateless across frames).
//!
//! Each replica executes whatever layer schedule its
//! [`PipelineConfig`](super::pipeline::PipelineConfig) selects: with
//! `pipelined` (the default) every
//! request runs on the streamed per-layer-worker executor inside its
//! replica thread — the inter-layer row streaming propagates here
//! automatically through `Pipeline::run`, composing replicas (across
//! frames) x layer workers (within a frame) x row bands (within a
//! layer).
//!
//! # Generations and hot swap
//!
//! The pool's queue + workers + metrics live in a *generation*. A
//! [`ReplicaPool::swap`] builds the next generation in the background
//! (new replicas, fresh queue, workers already running), atomically
//! redirects [`ReplicaPool::submit`] / [`ReplicaPool::try_submit`] to
//! it, then retires the old generation: its workers drain every job
//! that was queued before the redirect and only then exit. No request
//! is dropped and no reply receiver is left dangling — the property
//! the online auto-tuner (`crate::autotune`) and the zero-downtime
//! model-reload path (ROADMAP item 3) both build on. The redirect is
//! race-free because `submit` pushes while holding the generation
//! read lock: a concurrent swap's write lock cannot land between the
//! generation lookup and the push, so every accepted job reaches a
//! queue whose workers have not yet been told to stop.
//!
//! Per-replica counters aggregate in [`crate::metrics::PoolMetrics`]
//! (one set per generation — a swap starts fresh books sized to the
//! new replica count).
//!
//! # Supervision
//!
//! Every worker body runs the pipeline inside `catch_unwind`: a panic
//! (bug or injected fault) errors the in-flight frame — the submitter
//! gets a [`PoolResult`] with `error` set, never a hang — and the
//! worker consults the generation's [`Supervisor`]. Within the
//! [`RestartPolicy`] budget it backs off, optionally rebuilds its
//! pipeline through the [`PoolSupervision::rebuild`] factory, and
//! resumes; past the budget it retires and the pool degrades to the
//! survivors. When the *last* replica retires, the retiring worker
//! stays behind as a bouncer that answers every queued and future job
//! with an explicit error, so submitters always resolve and
//! [`ReplicaPool::drain`] still terminates. Lock poisoning (a panic
//! on another thread while a pool lock was held) is recovered with
//! `into_inner` everywhere — a crashed replica must never cascade
//! into panics across submitters.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::codec::SpikeFrame;
use crate::metrics::PoolMetrics;
use crate::supervise::{panic_message, FaultHooks, RestartPolicy,
                       Supervisor, SuperviseStats, Verdict};
use crate::telemetry::WorkloadObserver;

use super::batch::Batcher;
use super::pipeline::Pipeline;

/// Factory rebuilding replica `idx`'s pipeline after a caught panic
/// (`None` = keep serving with the existing engines; per-frame state
/// re-initializes on the next `begin_frame`). Wired by the session
/// from its `PoolRecipe` so a corrupted engine never survives a
/// restart.
pub type RebuildFn = Arc<dyn Fn(usize) -> Option<Pipeline> + Send + Sync>;

/// Supervision wiring shared by every generation of one pool.
#[derive(Clone)]
pub struct PoolSupervision {
    /// Restart budget per worker (rolling window, exponential backoff).
    pub policy: RestartPolicy,
    /// Fault-injection hooks (`None` in production).
    pub hooks: Option<Arc<FaultHooks>>,
    /// Pipeline rebuild factory for post-panic restarts.
    pub rebuild: Option<RebuildFn>,
    /// Shared counters (restarts, retirements, ...) exported by the
    /// metrics endpoint.
    pub stats: Arc<SuperviseStats>,
}

impl Default for PoolSupervision {
    fn default() -> Self {
        Self {
            policy: RestartPolicy::default(),
            hooks: None,
            rebuild: None,
            stats: Arc::new(SuperviseStats::default()),
        }
    }
}

/// One unit of work travelling to a replica.
pub struct PoolJob {
    pub id: u64,
    pub frame: SpikeFrame,
    pub enqueued_at: Instant,
    reply: Sender<PoolResult>,
}

/// What comes back.
#[derive(Debug, Clone)]
pub struct PoolResult {
    pub id: u64,
    /// Which replica served the request.
    pub replica: usize,
    /// Classifier argmax (None for nets without an FC head).
    pub prediction: Option<usize>,
    /// Accumulated classifier logits (empty for nets without a head).
    pub logits: Vec<f32>,
    /// End-to-end latency (queue wait + compute), µs.
    pub latency_us: u64,
    /// Why the frame was *not* served (replica panicked, every
    /// replica retired, ...). `None` on success.
    pub error: Option<String>,
}

/// What a completed [`ReplicaPool::swap`] reports.
#[derive(Debug, Clone, Copy)]
pub struct SwapStats {
    /// Index of the generation now serving (0 = the boot generation).
    pub generation: u64,
    /// Replica count of the new generation.
    pub replicas: usize,
    /// Jobs that were still owned by the old generation at the
    /// redirect and were drained to completion before it retired.
    pub drained: usize,
}

/// One queue + worker-set + metrics unit. The pool holds the active
/// generation behind a `RwLock`; a swap replaces it wholesale.
struct Generation {
    queue: Arc<Batcher<PoolJob>>,
    stop: Arc<AtomicBool>,
    metrics: Arc<PoolMetrics>,
    /// Jobs accepted but not yet replied to (incremented at submit,
    /// decremented after the reply is sent) — the drain condition.
    in_flight: Arc<AtomicU64>,
    replicas: usize,
    /// Replicas still serving (shrinks as workers retire past their
    /// restart budget; 0 = degraded to the error-bouncer).
    alive: Arc<AtomicUsize>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl Generation {
    fn spawn(pipelines: Vec<Pipeline>, max_batch: usize,
             max_wait: Duration, capacity: usize,
             observer: Option<Arc<WorkloadObserver>>,
             supervision: PoolSupervision) -> Self {
        assert!(!pipelines.is_empty(), "pool needs at least one replica");
        let queue =
            Arc::new(Batcher::with_capacity(max_batch, max_wait, capacity));
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(PoolMetrics::new(pipelines.len()));
        let in_flight = Arc::new(AtomicU64::new(0));
        let replicas = pipelines.len();
        let alive = Arc::new(AtomicUsize::new(replicas));
        // Restart budgets are per generation: a swap starts fresh.
        let supervisor =
            Arc::new(Supervisor::new(supervision.policy, replicas));
        let workers = pipelines
            .into_iter()
            .enumerate()
            .map(|(idx, mut pipe)| {
                let queue = queue.clone();
                let stop = stop.clone();
                let metrics = metrics.clone();
                let in_flight = in_flight.clone();
                let alive = alive.clone();
                let observer = observer.clone();
                let supervisor = supervisor.clone();
                let hooks = supervision.hooks.clone();
                let rebuild = supervision.rebuild.clone();
                let stats = supervision.stats.clone();
                std::thread::spawn(move || {
                    // Per-replica serve sequence, stable across
                    // restarts — the fault plans key on it.
                    let mut frame_seq: u64 = 0;
                    'serve: loop {
                        let batch = queue.next_batch();
                        if batch.is_empty() {
                            if stop.load(Ordering::SeqCst) {
                                return;
                            }
                            continue;
                        }
                        for job in batch {
                            let crashed = serve_one(
                                &mut pipe, idx, job, &metrics,
                                observer.as_deref(), hooks.as_deref(),
                                frame_seq);
                            frame_seq += 1;
                            in_flight.fetch_sub(1, Ordering::SeqCst);
                            if !crashed {
                                continue;
                            }
                            match supervisor.decide(idx) {
                                Verdict::Restart { delay } => {
                                    stats.replica_restarts
                                        .fetch_add(1, Ordering::SeqCst);
                                    std::thread::sleep(delay);
                                    if let Some(fresh) = rebuild
                                        .as_ref()
                                        .and_then(|rb| rb(idx))
                                    {
                                        pipe = fresh;
                                    }
                                }
                                Verdict::Retire => {
                                    stats.replicas_retired
                                        .fetch_add(1, Ordering::SeqCst);
                                    break 'serve;
                                }
                            }
                        }
                    }
                    // Retired. If other replicas survive they keep
                    // draining the shared queue; the *last* one to go
                    // stays as a bouncer erroring every job so
                    // submitters never hang and drains still finish.
                    if alive.fetch_sub(1, Ordering::SeqCst) > 1 {
                        return;
                    }
                    loop {
                        let batch = queue.next_batch();
                        if batch.is_empty() {
                            if stop.load(Ordering::SeqCst) {
                                return;
                            }
                            continue;
                        }
                        for job in batch {
                            metrics.record_error(idx);
                            fail_job(job, idx,
                                     "every replica retired (restart \
                                      budget exhausted)");
                            in_flight.fetch_sub(1, Ordering::SeqCst);
                        }
                    }
                })
            })
            .collect();
        Self {
            queue,
            stop,
            metrics,
            in_flight,
            replicas,
            alive,
            workers: Mutex::new(workers),
        }
    }

    fn push(&self, job: PoolJob) {
        // Count before pushing so a drain racing this submit can never
        // observe "idle" while the job is in neither counter nor queue.
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.queue.push(job);
    }

    fn try_push(&self, job: PoolJob) -> Result<(), PoolJob> {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        match self.queue.try_push(job) {
            Ok(()) => Ok(()),
            Err(job) => {
                self.in_flight.fetch_sub(1, Ordering::SeqCst);
                Err(job)
            }
        }
    }

    /// Wait until every accepted job has been replied to. Returns the
    /// number of jobs that were in flight on entry. Does not stop the
    /// workers — the generation keeps serving afterwards.
    fn drain(&self) -> usize {
        let pending = self.in_flight.load(Ordering::SeqCst) as usize;
        while self.in_flight.load(Ordering::SeqCst) > 0 {
            // A fully-retired generation (workers joined elsewhere)
            // cannot make progress; don't spin forever on its account.
            let ws =
                self.workers.lock().unwrap_or_else(|e| e.into_inner());
            if ws.iter().all(|w| w.is_finished()) {
                break;
            }
            drop(ws);
            std::thread::sleep(Duration::from_micros(500));
        }
        pending
    }

    /// Stop accepting progress, drain in-flight jobs, join workers.
    /// Returns the drained in-flight count. Idempotent.
    fn retire(&self) -> usize {
        self.stop.store(true, Ordering::SeqCst);
        let drained = self.drain();
        let workers: Vec<_> = self
            .workers
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .drain(..)
            .collect();
        for w in workers {
            let _ = w.join();
        }
        drained
    }
}

/// Answer `job` with an explicit error result (never a hang).
fn fail_job(job: PoolJob, idx: usize, msg: &str) {
    let _ = job.reply.send(PoolResult {
        id: job.id,
        replica: idx,
        prediction: None,
        logits: Vec::new(),
        latency_us: job.enqueued_at.elapsed().as_micros() as u64,
        error: Some(msg.to_string()),
    });
}

/// A pool of pipeline replicas behind one queue.
pub struct ReplicaPool {
    active: RwLock<Arc<Generation>>,
    generation: AtomicU64,
    max_batch: usize,
    max_wait: Duration,
    capacity: usize,
    observer: Option<Arc<WorkloadObserver>>,
    supervision: PoolSupervision,
    next_id: AtomicU64,
}

impl ReplicaPool {
    /// Spawn one worker per pipeline. `max_batch` / `max_wait` tune the
    /// shared queue's batching policy (`max_wait` also bounds shutdown
    /// latency — workers re-check the stop flag on every timeout).
    pub fn new(pipelines: Vec<Pipeline>, max_batch: usize,
               max_wait: Duration) -> Self {
        Self::with_capacity(pipelines, max_batch, max_wait, 0)
    }

    /// Like [`ReplicaPool::new`], with the shared queue bounded at
    /// `capacity` items (0 = unbounded). A bounded pool lets
    /// [`ReplicaPool::try_submit`] shed work explicitly instead of
    /// queueing without limit — the event-streaming backpressure path.
    pub fn with_capacity(pipelines: Vec<Pipeline>, max_batch: usize,
                         max_wait: Duration, capacity: usize) -> Self {
        Self::with_observer(pipelines, max_batch, max_wait, capacity, None)
    }

    /// Full constructor: an attached [`WorkloadObserver`] sees every
    /// served frame's per-layer codec ratios — the measured-workload
    /// feed the online auto-tuner re-plans from. Generations created
    /// by [`ReplicaPool::swap`] inherit the observer.
    pub fn with_observer(pipelines: Vec<Pipeline>, max_batch: usize,
                         max_wait: Duration, capacity: usize,
                         observer: Option<Arc<WorkloadObserver>>)
                         -> Self {
        Self::with_supervision(pipelines, max_batch, max_wait, capacity,
                               observer, PoolSupervision::default())
    }

    /// Full constructor: `supervision` carries the restart policy,
    /// the optional fault-injection hooks, the pipeline rebuild
    /// factory, and the shared supervision counters. Every generation
    /// (boot and swapped) inherits it; restart budgets reset per
    /// generation.
    pub fn with_supervision(pipelines: Vec<Pipeline>, max_batch: usize,
                            max_wait: Duration, capacity: usize,
                            observer: Option<Arc<WorkloadObserver>>,
                            supervision: PoolSupervision) -> Self {
        let gen = Generation::spawn(pipelines, max_batch, max_wait,
                                    capacity, observer.clone(),
                                    supervision.clone());
        Self {
            active: RwLock::new(Arc::new(gen)),
            generation: AtomicU64::new(0),
            max_batch,
            max_wait,
            capacity,
            observer,
            supervision,
            next_id: AtomicU64::new(0),
        }
    }

    fn active(&self) -> Arc<Generation> {
        self.active
            .read()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    /// Shared supervision counters (restarts, retirements, rollbacks).
    pub fn supervise_stats(&self) -> Arc<SuperviseStats> {
        self.supervision.stats.clone()
    }

    /// Fault-injection hooks, if this pool runs under a chaos plan.
    pub fn fault_hooks(&self) -> Option<Arc<FaultHooks>> {
        self.supervision.hooks.clone()
    }

    /// Replicas of the serving generation still alive (not retired by
    /// the supervisor). 0 = degraded to explicit-error service.
    pub fn alive_replicas(&self) -> usize {
        self.active().alive.load(Ordering::SeqCst)
    }

    /// Replica count of the serving generation.
    pub fn replicas(&self) -> usize {
        self.active().replicas
    }

    pub fn queue_len(&self) -> usize {
        self.active().queue.len()
    }

    /// Jobs accepted by the serving generation and not yet replied to
    /// (queued + being computed).
    pub fn in_flight(&self) -> usize {
        self.active().in_flight.load(Ordering::SeqCst) as usize
    }

    /// Index of the serving generation: 0 at boot, +1 per completed
    /// [`ReplicaPool::swap`].
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::SeqCst)
    }

    /// Serving counters of the *active* generation (a swap starts a
    /// fresh set sized to the new replica count).
    pub fn metrics(&self) -> Arc<PoolMetrics> {
        self.active().metrics.clone()
    }

    /// Enqueue a frame; the receiver yields the result when a replica
    /// has served it. Non-blocking — submit many, then collect.
    pub fn submit(&self, frame: SpikeFrame) -> Receiver<PoolResult> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // Push under the read guard: a concurrent swap cannot retire
        // this generation between lookup and push (see module docs).
        let gen = self.active.read().unwrap_or_else(|e| e.into_inner());
        gen.push(PoolJob {
            id,
            frame,
            enqueued_at: Instant::now(),
            reply: tx,
        });
        rx
    }

    /// Enqueue a frame unless the bounded queue is full, in which case
    /// the frame comes back in `Err` for the caller to shed or retry
    /// (always accepts on pools built with capacity 0).
    pub fn try_submit(&self, frame: SpikeFrame)
                      -> Result<Receiver<PoolResult>, SpikeFrame> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let gen = self.active.read().unwrap_or_else(|e| e.into_inner());
        match gen.try_push(PoolJob {
            id,
            frame,
            enqueued_at: Instant::now(),
            reply: tx,
        }) {
            Ok(()) => Ok(rx),
            Err(job) => Err(job.frame),
        }
    }

    /// Blocking convenience: submit one frame and wait for its result.
    pub fn infer(&self, frame: SpikeFrame) -> anyhow::Result<PoolResult> {
        self.submit(frame).recv().map_err(|_| {
            anyhow::anyhow!("replica pool dropped the reply (replica \
                             crashed or pool shut down)")
        })
    }

    /// Wait until every accepted job has been replied to, without
    /// stopping the workers; returns how many were in flight when the
    /// drain began. The same wait is what a generation swap runs while
    /// retiring the old replica set.
    pub fn drain(&self) -> usize {
        self.active().drain()
    }

    /// Zero-downtime hot swap: start serving from `pipelines` without
    /// dropping a single in-flight or future request. The new
    /// generation's workers are already running when `submit` /
    /// `try_submit` are redirected to it; the old generation then
    /// drains everything it accepted (the [`ReplicaPool::drain`]
    /// wait) and retires. Blocks until the old generation is fully
    /// drained and joined.
    pub fn swap(&self, pipelines: Vec<Pipeline>) -> SwapStats {
        let fresh = Arc::new(Generation::spawn(
            pipelines, self.max_batch, self.max_wait, self.capacity,
            self.observer.clone(), self.supervision.clone()));
        let replicas = fresh.replicas;
        let old = {
            let mut active =
                self.active.write().unwrap_or_else(|e| e.into_inner());
            std::mem::replace(&mut *active, fresh)
        };
        let generation = self.generation.fetch_add(1, Ordering::SeqCst) + 1;
        let drained = old.retire();
        SwapStats { generation, replicas, drained }
    }

    /// Stop accepting work, let workers drain the queue, and join them
    /// inline.
    pub fn shutdown(self) {
        self.active().retire();
    }
}

impl Drop for ReplicaPool {
    fn drop(&mut self) {
        self.active().retire();
    }
}

/// Serve one job with panic isolation. Returns `true` when the
/// pipeline panicked (caught): the job was answered with an error
/// result and the caller must consult the supervisor.
fn serve_one(pipe: &mut Pipeline, idx: usize, job: PoolJob,
             metrics: &PoolMetrics, observer: Option<&WorkloadObserver>,
             hooks: Option<&FaultHooks>, frame_seq: u64) -> bool {
    let fault = hooks
        .map(|h| h.on_serve(idx, frame_seq))
        .unwrap_or_default();
    if let Some(d) = fault.slow {
        std::thread::sleep(d);
    }
    let t0 = Instant::now();
    // AssertUnwindSafe: on a caught panic the pipeline's engine state
    // is treated as poisoned — the supervisor rebuilds it (or the
    // next `begin_frame` re-initializes per-frame state) before it
    // serves again, and the frame itself is answered as an error.
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        if fault.panic {
            panic!("injected fault: panic_at replica={idx} \
                    frame={frame_seq}");
        }
        pipe.run(std::slice::from_ref(&job.frame))
    }));
    let busy_us = t0.elapsed().as_micros() as u64;
    let latency_us = job.enqueued_at.elapsed().as_micros() as u64;
    match run {
        Ok(rep) => {
            let prediction = rep.predictions.first().copied();
            if prediction.is_none() {
                metrics.record_error(idx);
            } else {
                metrics.record(idx, latency_us, busy_us);
            }
            if let Some(obs) = observer {
                obs.observe(&rep.layer_names, &rep.codec_ratios,
                            rep.frames);
            }
            if fault.drop_reply {
                // Injected reply loss: dropping the sender makes the
                // submitter's `recv` fail fast — an explicit error on
                // its side, never a hang.
                return false;
            }
            let _ = job.reply.send(PoolResult {
                id: job.id,
                replica: idx,
                prediction,
                logits: rep.logits.first().cloned().unwrap_or_default(),
                latency_us,
                error: None,
            });
            false
        }
        Err(payload) => {
            metrics.record_error(idx);
            if let Some(tr) = &pipe.config.trace {
                let t = tr.start();
                tr.record("replica.panic", "fault", t,
                          [("replica", idx as u64),
                           ("frame", frame_seq)]);
            }
            let _ = job.reply.send(PoolResult {
                id: job.id,
                replica: idx,
                prediction: None,
                logits: Vec::new(),
                latency_us,
                error: Some(format!("replica {idx} panicked: {}",
                                    panic_message(payload.as_ref()))),
            });
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch;
    use crate::coordinator::pipeline::PipelineConfig;
    use crate::sim::backend::BackendKind;
    use crate::util::rng::Rng;

    fn mini_net() -> arch::NetworkSpec {
        arch::NetBuilder::new("mini", (10, 10, 2))
            .encoder(4, 3)
            .conv(6, 3)
            .pool()
            .fc(10)
            .build()
    }

    fn pipes_with(n: usize, backend: BackendKind) -> Vec<Pipeline> {
        (0..n)
            .map(|_| {
                Pipeline::random(
                    mini_net(),
                    PipelineConfig { backend, ..Default::default() },
                )
                .unwrap()
            })
            .collect()
    }

    fn pipes(n: usize) -> Vec<Pipeline> {
        pipes_with(n, BackendKind::WordParallel)
    }

    fn frames(n: usize, seed: u64) -> Vec<SpikeFrame> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| SpikeFrame::random(10, 10, 4, 0.3, &mut rng))
            .collect()
    }

    /// Pool results match a single serial pipeline, independent of how
    /// many replicas raced over the queue.
    #[test]
    fn pool_matches_serial_pipeline() {
        let fs = frames(12, 1);
        let mut serial = pipes(1).pop().unwrap();
        let want: Vec<usize> = fs
            .iter()
            .map(|f| serial.run(std::slice::from_ref(f)).predictions[0])
            .collect();

        for n in [1usize, 3] {
            let pool =
                ReplicaPool::new(pipes(n), 4, Duration::from_millis(2));
            let rxs: Vec<_> =
                fs.iter().map(|f| pool.submit(f.clone())).collect();
            let got: Vec<usize> = rxs
                .into_iter()
                .map(|rx| rx.recv().unwrap().prediction.unwrap())
                .collect();
            assert_eq!(got, want, "replicas={n}");
            let totals = pool.metrics().totals();
            assert_eq!(totals.requests, fs.len() as u64);
            assert_eq!(totals.errors, 0);
            pool.shutdown();
        }
    }

    /// Per-replica counters sum to the pool totals, and with >1 replica
    /// under enough load more than one replica does work.
    #[test]
    fn metrics_split_across_replicas() {
        let pool = ReplicaPool::new(pipes(2), 1, Duration::from_millis(2));
        let fs = frames(16, 2);
        let rxs: Vec<_> =
            fs.iter().map(|f| pool.submit(f.clone())).collect();
        let mut served_by = std::collections::BTreeSet::new();
        for rx in rxs {
            served_by.insert(rx.recv().unwrap().replica);
        }
        let m = pool.metrics();
        let per: u64 =
            m.per_replica().iter().map(|s| s.requests).sum();
        assert_eq!(per, m.totals().requests);
        assert_eq!(m.totals().requests, 16);
        // Both replicas exist in the books even if one drained all.
        assert_eq!(m.per_replica().len(), 2);
        assert!(!served_by.is_empty());
        pool.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_work() {
        let pool = ReplicaPool::new(pipes(2), 2, Duration::from_millis(1));
        let rxs: Vec<_> = frames(8, 3)
            .into_iter()
            .map(|f| pool.submit(f))
            .collect();
        pool.shutdown(); // workers drain the queue before exiting
        for rx in rxs {
            assert!(rx.recv().is_ok(), "queued job dropped at shutdown");
        }
    }

    /// A bounded pool sheds (returns) frames past capacity instead of
    /// queueing them; submitted work still completes normally.
    #[test]
    fn bounded_pool_sheds_past_capacity() {
        let pool = ReplicaPool::with_capacity(pipes(1), 1,
                                              Duration::from_millis(1), 2);
        let fs = frames(8, 9);
        let mut rxs = Vec::new();
        let mut shed = 0;
        for f in fs {
            match pool.try_submit(f) {
                Ok(rx) => rxs.push(rx),
                Err(back) => {
                    assert_eq!((back.h, back.w, back.c), (10, 10, 4));
                    shed += 1;
                }
            }
        }
        // Depth 2 + whatever the worker drained: at least one of the 8
        // burst frames must have been shed, and none may hang.
        assert!(shed >= 1, "burst past a depth-2 queue must shed");
        for rx in rxs {
            assert!(rx.recv().unwrap().prediction.is_some());
        }
        assert_eq!(pool.metrics().totals().requests, (8 - shed) as u64);
        pool.shutdown();
    }

    #[test]
    fn infer_blocks_for_result() {
        let pool = ReplicaPool::new(pipes(1), 4, Duration::from_millis(2));
        let r = pool.infer(frames(1, 4).pop().unwrap()).unwrap();
        assert!(r.prediction.is_some());
        assert_eq!(r.logits.len(), 10);
        assert_eq!(r.replica, 0);
        pool.shutdown();
    }

    /// Regression for the pending-reply-loss class of bug: every
    /// receiver handed out before, during, and after a swap resolves —
    /// the old generation drains everything it accepted before
    /// retiring, and redirected submits land on live workers.
    #[test]
    fn swap_preserves_every_pending_reply() {
        let fs = frames(24, 5);
        let mut serial = pipes(1).pop().unwrap();
        let want: Vec<usize> = fs
            .iter()
            .map(|f| serial.run(std::slice::from_ref(f)).predictions[0])
            .collect();

        let pool = ReplicaPool::new(pipes(2), 2, Duration::from_millis(1));
        assert_eq!(pool.generation(), 0);
        let rxs_before: Vec<_> = fs[..12]
            .iter()
            .map(|f| pool.submit(f.clone()))
            .collect();
        // Swap while the first half is still queued/in flight; the new
        // generation runs a different host backend (results bit-exact).
        let stats = pool.swap(pipes_with(3, BackendKind::Accurate));
        assert_eq!(stats.generation, 1);
        assert_eq!(stats.replicas, 3);
        assert_eq!(pool.generation(), 1);
        assert_eq!(pool.replicas(), 3);
        let rxs_after: Vec<_> = fs[12..]
            .iter()
            .map(|f| pool.submit(f.clone()))
            .collect();
        let got: Vec<usize> = rxs_before
            .into_iter()
            .chain(rxs_after)
            .map(|rx| {
                rx.recv().expect("reply lost across swap")
                    .prediction
                    .unwrap()
            })
            .collect();
        assert_eq!(got, want);
        pool.shutdown();
    }

    /// Swapping to an identically-configured replica set is invisible
    /// in the results: logits and predictions are bit-identical before
    /// and after (the bit-exactness contract the auto-tuner leans on).
    #[test]
    fn swap_to_identical_config_is_bit_exact() {
        let fs = frames(6, 6);
        let pool = ReplicaPool::new(pipes(1), 4, Duration::from_millis(1));
        let before: Vec<_> = fs
            .iter()
            .map(|f| pool.infer(f.clone()).unwrap())
            .collect();
        pool.swap(pipes(1));
        let after: Vec<_> = fs
            .iter()
            .map(|f| pool.infer(f.clone()).unwrap())
            .collect();
        for (a, b) in before.iter().zip(&after) {
            assert_eq!(a.prediction, b.prediction);
            assert_eq!(a.logits, b.logits, "logits drifted across swap");
        }
        pool.shutdown();
    }

    /// `drain` waits out the backlog without stopping the pool: the
    /// queue is empty afterwards and new submits still complete.
    #[test]
    fn drain_leaves_the_pool_serving() {
        let pool = ReplicaPool::new(pipes(1), 2, Duration::from_millis(1));
        let rxs: Vec<_> = frames(6, 7)
            .into_iter()
            .map(|f| pool.submit(f))
            .collect();
        let drained = pool.drain();
        assert!(drained <= 6, "at most the submitted jobs: {drained}");
        assert_eq!(pool.in_flight(), 0);
        assert_eq!(pool.queue_len(), 0);
        for rx in rxs {
            // Drained means replied: these must already be resolved.
            assert!(rx.try_recv().is_ok(), "drain returned before reply");
        }
        // Still alive: a post-drain submit is served normally.
        let r = pool.infer(frames(1, 8).pop().unwrap()).unwrap();
        assert!(r.prediction.is_some());
        pool.shutdown();
    }

    /// A swap starts fresh metrics books sized to the new generation.
    #[test]
    fn swap_resets_metrics_to_new_replica_count() {
        let pool = ReplicaPool::new(pipes(1), 4, Duration::from_millis(1));
        pool.infer(frames(1, 10).pop().unwrap()).unwrap();
        assert_eq!(pool.metrics().totals().requests, 1);
        pool.swap(pipes(2));
        let m = pool.metrics();
        assert_eq!(m.per_replica().len(), 2);
        assert_eq!(m.totals().requests, 0);
        pool.shutdown();
    }

    use crate::supervise::{FaultEvent, FaultPlan};

    fn supervised_pool(n: usize, plan: FaultPlan,
                       policy: RestartPolicy) -> ReplicaPool {
        let sup = PoolSupervision {
            policy,
            hooks: Some(Arc::new(FaultHooks::from_plan(plan))),
            rebuild: Some(Arc::new(|_idx| {
                Pipeline::random(mini_net(), PipelineConfig {
                    backend: BackendKind::WordParallel,
                    ..Default::default()
                })
                .ok()
            })),
            stats: Arc::new(SuperviseStats::default()),
        };
        ReplicaPool::with_supervision(pipes(n), 4,
                                      Duration::from_millis(1), 0,
                                      None, sup)
    }

    /// An injected panic errors exactly its own frame; the worker
    /// restarts (counted) and keeps serving bit-identical results.
    #[test]
    fn panicking_replica_errors_frame_and_restarts() {
        let plan = FaultPlan::new(0, vec![
            FaultEvent::PanicAt { replica: 0, frame: 1 },
        ]);
        let pool = supervised_pool(1, plan, RestartPolicy::default());
        let fs = frames(4, 21);
        let mut serial = pipes(1).pop().unwrap();
        for (i, f) in fs.iter().enumerate() {
            let r = pool.infer(f.clone()).unwrap();
            if i == 1 {
                let err = r.error.expect("crashed frame must error");
                assert!(err.contains("panicked"), "{err}");
                assert_eq!(r.prediction, None);
            } else {
                assert!(r.error.is_none());
                assert_eq!(r.prediction.unwrap(),
                           serial.run(std::slice::from_ref(f))
                               .predictions[0],
                           "surviving serves stay bit-identical");
            }
        }
        let snap = pool.supervise_stats().snapshot();
        assert_eq!(snap.replica_restarts, 1);
        assert_eq!(snap.replicas_retired, 0);
        assert_eq!(pool.alive_replicas(), 1);
        pool.shutdown();
    }

    /// Past the restart budget the replica retires; with no survivors
    /// the pool answers every subsequent job with an explicit error —
    /// zero hangs, shutdown still drains.
    #[test]
    fn budget_exhaustion_degrades_to_explicit_errors() {
        let plan = FaultPlan::new(0, vec![
            FaultEvent::PanicAt { replica: 0, frame: 0 },
        ]);
        let pool = supervised_pool(1, plan, RestartPolicy::never());
        let r = pool.infer(frames(1, 22).pop().unwrap()).unwrap();
        assert!(r.error.as_deref().unwrap().contains("panicked"));
        // The sole replica is now retired: served by the bouncer.
        let r = pool.infer(frames(1, 23).pop().unwrap()).unwrap();
        assert!(r.error.as_deref().unwrap().contains("retired"),
                "degraded pool must answer, got {r:?}");
        let snap = pool.supervise_stats().snapshot();
        assert_eq!(snap.replica_restarts, 0);
        assert_eq!(snap.replicas_retired, 1);
        assert_eq!(pool.alive_replicas(), 0);
        pool.shutdown();
    }

    /// Restart counts respect the rolling budget: a crash-looping
    /// replica is granted at most `max_restarts` restarts per window.
    #[test]
    fn restart_counts_respect_the_budget() {
        let plan = FaultPlan::new(0, (0..8)
            .map(|i| FaultEvent::PanicAt { replica: 0, frame: i })
            .collect());
        let policy = RestartPolicy {
            max_restarts: 2,
            window: Duration::from_secs(3600),
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(2),
        };
        let pool = supervised_pool(1, plan, policy);
        for f in frames(8, 24) {
            let r = pool.infer(f).unwrap();
            assert!(r.error.is_some(), "every frame either panics or \
                                        hits the retired bouncer");
        }
        let snap = pool.supervise_stats().snapshot();
        assert_eq!(snap.replica_restarts, 2, "budget is the cap");
        assert_eq!(snap.replicas_retired, 1);
        pool.shutdown();
    }

    /// A dropped reply surfaces as a fast receive error on the
    /// submitter side — explicit failure, not a hang.
    #[test]
    fn drop_reply_fault_fails_fast() {
        let plan = FaultPlan::new(0, vec![
            FaultEvent::DropReply { replica: 0, frame: 0 },
        ]);
        let pool = supervised_pool(1, plan, RestartPolicy::default());
        let err = pool.infer(frames(1, 25).pop().unwrap()).unwrap_err();
        assert!(err.to_string().contains("dropped the reply"), "{err}");
        // The worker did not crash: the next frame serves normally.
        let r = pool.infer(frames(1, 26).pop().unwrap()).unwrap();
        assert!(r.error.is_none());
        assert!(r.prediction.is_some());
        pool.shutdown();
    }

    /// Survivors keep serving (bit-identically) while another replica
    /// crash-loops into retirement.
    #[test]
    fn survivors_unaffected_by_a_retired_replica() {
        let plan = FaultPlan::new(0, (0..4)
            .map(|i| FaultEvent::PanicAt { replica: 0, frame: i })
            .collect());
        let pool = supervised_pool(2, plan, RestartPolicy::never());
        let fs = frames(24, 27);
        let mut serial = pipes(1).pop().unwrap();
        let mut errored = 0;
        for f in &fs {
            let r = pool.infer(f.clone()).unwrap();
            match r.error {
                Some(_) => errored += 1,
                None => assert_eq!(
                    r.prediction.unwrap(),
                    serial.run(std::slice::from_ref(f)).predictions[0]),
            }
        }
        assert!(errored <= 1, "only replica 0's first serve crashes");
        assert!(pool.alive_replicas() >= 1);
        pool.shutdown();
    }
}
