//! Multi-pipeline parallel serving: N accelerator-pipeline replicas
//! draining one shared work queue.
//!
//! The paper's system is one physical accelerator; the reproduction's
//! north star is a *production* simulator that saturates the host, so
//! the coordinator generalises from one pipeline to a configurable
//! pool of replicas. Each replica owns a full [`Pipeline`] (its own
//! engines and weight copies — no sharing, no locks on the hot path)
//! and a worker thread that drains the shared [`Batcher`] queue.
//! Throughput scales with host cores while per-request results stay
//! identical to a single pipeline (pinned by tests — the pipeline is
//! stateless across frames).
//!
//! Each replica executes whatever layer schedule its
//! [`PipelineConfig`](super::pipeline::PipelineConfig) selects: with
//! `pipelined` (the default) every
//! request runs on the streamed per-layer-worker executor inside its
//! replica thread — the inter-layer row streaming propagates here
//! automatically through `Pipeline::run`, composing replicas (across
//! frames) x layer workers (within a frame) x row bands (within a
//! layer).
//!
//! Per-replica counters aggregate in [`crate::metrics::PoolMetrics`].

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::codec::SpikeFrame;
use crate::metrics::PoolMetrics;

use super::batch::Batcher;
use super::pipeline::Pipeline;

/// One unit of work travelling to a replica.
pub struct PoolJob {
    pub id: u64,
    pub frame: SpikeFrame,
    pub enqueued_at: Instant,
    reply: Sender<PoolResult>,
}

/// What comes back.
#[derive(Debug, Clone)]
pub struct PoolResult {
    pub id: u64,
    /// Which replica served the request.
    pub replica: usize,
    /// Classifier argmax (None for nets without an FC head).
    pub prediction: Option<usize>,
    /// Accumulated classifier logits (empty for nets without a head).
    pub logits: Vec<f32>,
    /// End-to-end latency (queue wait + compute), µs.
    pub latency_us: u64,
}

/// A pool of pipeline replicas behind one queue.
pub struct ReplicaPool {
    queue: Arc<Batcher<PoolJob>>,
    stop: Arc<AtomicBool>,
    metrics: Arc<PoolMetrics>,
    workers: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl ReplicaPool {
    /// Spawn one worker per pipeline. `max_batch` / `max_wait` tune the
    /// shared queue's batching policy (`max_wait` also bounds shutdown
    /// latency — workers re-check the stop flag on every timeout).
    pub fn new(pipelines: Vec<Pipeline>, max_batch: usize,
               max_wait: Duration) -> Self {
        Self::with_capacity(pipelines, max_batch, max_wait, 0)
    }

    /// Like [`ReplicaPool::new`], with the shared queue bounded at
    /// `capacity` items (0 = unbounded). A bounded pool lets
    /// [`ReplicaPool::try_submit`] shed work explicitly instead of
    /// queueing without limit — the event-streaming backpressure path.
    pub fn with_capacity(pipelines: Vec<Pipeline>, max_batch: usize,
                         max_wait: Duration, capacity: usize) -> Self {
        assert!(!pipelines.is_empty(), "pool needs at least one replica");
        let queue =
            Arc::new(Batcher::with_capacity(max_batch, max_wait, capacity));
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(PoolMetrics::new(pipelines.len()));
        let workers = pipelines
            .into_iter()
            .enumerate()
            .map(|(idx, mut pipe)| {
                let queue = queue.clone();
                let stop = stop.clone();
                let metrics = metrics.clone();
                std::thread::spawn(move || {
                    loop {
                        let batch = queue.next_batch();
                        if batch.is_empty() {
                            if stop.load(Ordering::SeqCst) {
                                break;
                            }
                            continue;
                        }
                        for job in batch {
                            serve_one(&mut pipe, idx, job, &metrics);
                        }
                    }
                })
            })
            .collect();
        Self {
            queue,
            stop,
            metrics,
            workers,
            next_id: AtomicU64::new(0),
        }
    }

    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn metrics(&self) -> Arc<PoolMetrics> {
        self.metrics.clone()
    }

    /// Enqueue a frame; the receiver yields the result when a replica
    /// has served it. Non-blocking — submit many, then collect.
    pub fn submit(&self, frame: SpikeFrame) -> Receiver<PoolResult> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.queue.push(PoolJob {
            id,
            frame,
            enqueued_at: Instant::now(),
            reply: tx,
        });
        rx
    }

    /// Enqueue a frame unless the bounded queue is full, in which case
    /// the frame comes back in `Err` for the caller to shed or retry
    /// (always accepts on pools built with capacity 0).
    pub fn try_submit(&self, frame: SpikeFrame)
                      -> Result<Receiver<PoolResult>, SpikeFrame> {
        let (tx, rx) = channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        match self.queue.try_push(PoolJob {
            id,
            frame,
            enqueued_at: Instant::now(),
            reply: tx,
        }) {
            Ok(()) => Ok(rx),
            Err(job) => Err(job.frame),
        }
    }

    /// Blocking convenience: submit one frame and wait for its result.
    pub fn infer(&self, frame: SpikeFrame) -> anyhow::Result<PoolResult> {
        self.submit(frame)
            .recv()
            .map_err(|_| anyhow::anyhow!("replica pool shut down"))
    }

    /// Stop accepting work, let workers drain the queue, and join them.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ReplicaPool {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn serve_one(pipe: &mut Pipeline, idx: usize, job: PoolJob,
             metrics: &PoolMetrics) {
    let t0 = Instant::now();
    let rep = pipe.run(std::slice::from_ref(&job.frame));
    let busy_us = t0.elapsed().as_micros() as u64;
    let latency_us = job.enqueued_at.elapsed().as_micros() as u64;
    let prediction = rep.predictions.first().copied();
    if prediction.is_none() {
        metrics.record_error(idx);
    } else {
        metrics.record(idx, latency_us, busy_us);
    }
    let _ = job.reply.send(PoolResult {
        id: job.id,
        replica: idx,
        prediction,
        logits: rep.logits.first().cloned().unwrap_or_default(),
        latency_us,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch;
    use crate::coordinator::pipeline::PipelineConfig;
    use crate::sim::backend::BackendKind;
    use crate::util::rng::Rng;

    fn mini_net() -> arch::NetworkSpec {
        arch::NetBuilder::new("mini", (10, 10, 2))
            .encoder(4, 3)
            .conv(6, 3)
            .pool()
            .fc(10)
            .build()
    }

    fn pipes(n: usize) -> Vec<Pipeline> {
        (0..n)
            .map(|_| {
                Pipeline::random(
                    mini_net(),
                    PipelineConfig {
                        backend: BackendKind::WordParallel,
                        ..Default::default()
                    },
                )
                .unwrap()
            })
            .collect()
    }

    fn frames(n: usize, seed: u64) -> Vec<SpikeFrame> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| SpikeFrame::random(10, 10, 4, 0.3, &mut rng))
            .collect()
    }

    /// Pool results match a single serial pipeline, independent of how
    /// many replicas raced over the queue.
    #[test]
    fn pool_matches_serial_pipeline() {
        let fs = frames(12, 1);
        let mut serial = pipes(1).pop().unwrap();
        let want: Vec<usize> = fs
            .iter()
            .map(|f| serial.run(std::slice::from_ref(f)).predictions[0])
            .collect();

        for n in [1usize, 3] {
            let pool =
                ReplicaPool::new(pipes(n), 4, Duration::from_millis(2));
            let rxs: Vec<_> =
                fs.iter().map(|f| pool.submit(f.clone())).collect();
            let got: Vec<usize> = rxs
                .into_iter()
                .map(|rx| rx.recv().unwrap().prediction.unwrap())
                .collect();
            assert_eq!(got, want, "replicas={n}");
            let totals = pool.metrics().totals();
            assert_eq!(totals.requests, fs.len() as u64);
            assert_eq!(totals.errors, 0);
            pool.shutdown();
        }
    }

    /// Per-replica counters sum to the pool totals, and with >1 replica
    /// under enough load more than one replica does work.
    #[test]
    fn metrics_split_across_replicas() {
        let pool = ReplicaPool::new(pipes(2), 1, Duration::from_millis(2));
        let fs = frames(16, 2);
        let rxs: Vec<_> =
            fs.iter().map(|f| pool.submit(f.clone())).collect();
        let mut served_by = std::collections::BTreeSet::new();
        for rx in rxs {
            served_by.insert(rx.recv().unwrap().replica);
        }
        let m = pool.metrics();
        let per: u64 =
            m.per_replica().iter().map(|s| s.requests).sum();
        assert_eq!(per, m.totals().requests);
        assert_eq!(m.totals().requests, 16);
        // Both replicas exist in the books even if one drained all.
        assert_eq!(m.per_replica().len(), 2);
        assert!(!served_by.is_empty());
        pool.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_work() {
        let pool = ReplicaPool::new(pipes(2), 2, Duration::from_millis(1));
        let rxs: Vec<_> = frames(8, 3)
            .into_iter()
            .map(|f| pool.submit(f))
            .collect();
        pool.shutdown(); // workers drain the queue before exiting
        for rx in rxs {
            assert!(rx.recv().is_ok(), "queued job dropped at shutdown");
        }
    }

    /// A bounded pool sheds (returns) frames past capacity instead of
    /// queueing them; submitted work still completes normally.
    #[test]
    fn bounded_pool_sheds_past_capacity() {
        let pool = ReplicaPool::with_capacity(pipes(1), 1,
                                              Duration::from_millis(1), 2);
        let fs = frames(8, 9);
        let mut rxs = Vec::new();
        let mut shed = 0;
        for f in fs {
            match pool.try_submit(f) {
                Ok(rx) => rxs.push(rx),
                Err(back) => {
                    assert_eq!((back.h, back.w, back.c), (10, 10, 4));
                    shed += 1;
                }
            }
        }
        // Depth 2 + whatever the worker drained: at least one of the 8
        // burst frames must have been shed, and none may hang.
        assert!(shed >= 1, "burst past a depth-2 queue must shed");
        for rx in rxs {
            assert!(rx.recv().unwrap().prediction.is_some());
        }
        assert_eq!(pool.metrics().totals().requests, (8 - shed) as u64);
        pool.shutdown();
    }

    #[test]
    fn infer_blocks_for_result() {
        let pool = ReplicaPool::new(pipes(1), 4, Duration::from_millis(2));
        let r = pool.infer(frames(1, 4).pop().unwrap()).unwrap();
        assert!(r.prediction.is_some());
        assert_eq!(r.logits.len(), 10);
        assert_eq!(r.replica, 0);
        pool.shutdown();
    }
}
