//! The layer-wise pipelined streaming accelerator (paper Fig. 5/9).
//!
//! Every network layer gets a dedicated hardware engine; layers are
//! connected by FIFOs carrying spike-event-encoded frames
//! (SectionIV-E.1).  Frames stream through with the classic pipeline
//! timing of Eq. (10): after the pipe fills, a new frame completes
//! every `T_max` (bottleneck layer) cycles.
//!
//! With `pipelined` on the executor *runs* that schedule: one worker
//! thread per layer, connected by bounded row channels
//! (`sim::fifo::row_channel`) carrying word-packed completed output
//! rows into the next layer's staged input — a frame flows through all
//! layers concurrently, exactly as Fig. 9 overlaps them in time. The
//! serial schedule remains (`pipelined: false`, or single-layer nets)
//! and both produce bit-identical reports: every cycle/op/traffic
//! charge goes through the same engine code, only interleaved
//! differently in wall-clock time, and the totals are order-independent
//! sums. The integration tests cross-check the cycle accounting
//! against `dataflow::pipeline_latency` (Eq. 10).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::arch::NetworkSpec;
use crate::codec::{EventCodec, SpikeFrame};
use crate::dataflow::ConvLatencyParams;
use crate::sim::backend::BackendKind;
use crate::sim::energy::{EnergyModel, EnergyReport};
use crate::sim::engine::{build_engines, random_sources, EngineConfig,
                         LayerEngine, LayerResult, LayerWeights};
use crate::sim::fifo::{row_channel, ChannelSnapshot, RowReceiver,
                       RowSender, RowWait};
use crate::sim::memory::AccessCounter;
use crate::sim::resources::{ResourceModel, ResourceReport};
use crate::sim::{cycles_to_ms, CLK_HZ};
use crate::supervise::{panic_message, Deadline, FaultHooks,
                       SuperviseStats, WatchdogPolicy};
use crate::telemetry::TraceSink;

/// Poll granularity for deadline-sliced channel waits: long enough to
/// stay off the hot path, short enough that an expired deadline is
/// noticed promptly.
const WATCHDOG_SLICE: Duration = Duration::from_millis(5);

/// Pipeline construction options.
#[derive(Clone)]
pub struct PipelineConfig {
    pub timesteps: usize,
    pub timing: ConvLatencyParams,
    /// Layer-wise pipelining on (Eq. 10) or off (frames serialised).
    /// The single knob: it selects both the cycle-accounting formula
    /// AND the execution schedule (streamed per-layer workers vs the
    /// serial layer loop). Reports are bit-identical either way.
    pub pipelined: bool,
    /// Depth (rows in flight) of each inter-layer row channel when the
    /// streamed schedule runs. Any value >= 1 is deadlock-free; deeper
    /// channels absorb burstier producers. Host-side only — no effect
    /// on any architectural report.
    pub channel_capacity: usize,
    pub energy: EnergyModel,
    pub resources: ResourceModel,
    /// Functional compute backend for every engine (bit-exact across
    /// kinds; cycle / traffic reports are identical — `sim::backend`).
    pub backend: BackendKind,
    /// Intra-frame row bands per conv engine (scoped worker threads;
    /// host-side speed only — reports are band-invariant). Default 1.
    pub intra_parallel: usize,
    /// Telemetry span recorder shared with every engine, worker, and
    /// row channel (None = tracing off, the default). Purely
    /// observational — `tests/prop_telemetry.rs` pins that every
    /// architectural report field is identical with tracing on.
    pub trace: Option<Arc<TraceSink>>,
    /// Deadline monitor over the streamed schedule (None = off, the
    /// default). An overdue frame aborts every layer worker, tears the
    /// channels down, and — when `retry_serial` — re-runs the batch on
    /// the serial schedule, which produces a bit-identical report.
    pub watchdog: Option<WatchdogPolicy>,
    /// Runtime fault-injection hooks (`serve --chaos`); `None` in
    /// production, so the hot path never consults a plan.
    pub faults: Option<Arc<FaultHooks>>,
    /// Supervision counters ticked on watchdog fires / stream
    /// recoveries (shared with the pool and the metrics endpoint).
    pub supervise: Option<Arc<SuperviseStats>>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            timesteps: 1,
            timing: ConvLatencyParams::optimized(),
            pipelined: true,
            channel_capacity: 4,
            energy: EnergyModel::default(),
            resources: ResourceModel::default(),
            backend: BackendKind::Accurate,
            intra_parallel: 1,
            trace: None,
            watchdog: None,
            faults: None,
            supervise: None,
        }
    }
}

/// Aggregated results of running N frames through the pipeline.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub frames: u64,
    /// Per-layer cycles for ONE frame (all timesteps).
    pub layer_cycles: Vec<u64>,
    /// Per-layer names for reporting.
    pub layer_names: Vec<String>,
    /// Pipeline interval = max layer cycles (Eq. 11 asymptote).
    pub t_max: u64,
    /// Sum of per-layer cycles (unpipelined frame latency).
    pub t_sum: u64,
    /// Total cycles for the batch under the configured mode.
    pub total_cycles: u64,
    /// Synaptic ops per frame.
    pub ops_per_frame: u64,
    /// Aggregated memory traffic (whole batch).
    pub counters: AccessCounter,
    /// Per-layer dynamic energy for ONE frame.
    pub layer_energy: Vec<EnergyReport>,
    /// Per-layer Vmem buffer bytes (0 at T = 1 — Fig. 11).
    pub layer_vmem_bytes: Vec<usize>,
    /// Inter-layer event-stream compression ratios.
    pub codec_ratios: Vec<f64>,
    /// Classifier outputs per frame.
    pub predictions: Vec<usize>,
    /// Accumulated classifier logits per frame (serving path).
    pub logits: Vec<Vec<f32>>,
    /// Design resources.
    pub resources: ResourceReport,
    /// PE count of the design.
    pub pes: usize,
    /// Per-link row-channel counters of the streamed schedule (link
    /// `i` connects layer `i` to `i+1`; empty on the serial
    /// schedule). Host-timing-dependent observability data — NOT an
    /// architectural quantity, and excluded from every bit-exactness
    /// comparison.
    pub channel_stats: Vec<ChannelSnapshot>,
}

impl PipelineReport {
    pub fn fps(&self) -> f64 {
        self.frames as f64 / (self.total_cycles as f64 / CLK_HZ)
    }

    pub fn latency_ms_per_frame(&self) -> f64 {
        cycles_to_ms(self.total_cycles) / self.frames as f64
    }

    pub fn dynamic_energy_per_frame_j(&self) -> f64 {
        self.layer_energy.iter().map(|e| e.total_j()).sum()
    }

    /// Average power (W) at the achieved FPS.
    pub fn avg_power(&self, model: &EnergyModel) -> f64 {
        model.avg_power(
            self.dynamic_energy_per_frame_j(),
            self.fps(),
            self.pes,
            self.resources.bram36,
        )
    }
}

/// The streaming pipeline: one boxed [`LayerEngine`] per accelerated
/// layer, composed through the trait — new layer kinds are one impl
/// (`sim::engine`), not a coordinator edit.
pub struct Pipeline {
    pub net: NetworkSpec,
    pub config: PipelineConfig,
    engines: Vec<Box<dyn LayerEngine>>,
    codecs: Vec<Option<EventCodec>>,
    /// Per-layer activation buffers, reused across frames (the
    /// zero-allocation hot path: engines write into these through
    /// [`LayerEngine::process_frame_into`]).
    bufs: Vec<SpikeFrame>,
    /// Per-worker staged input frames for the streamed schedule
    /// (worker `i > 0` assembles layer `i-1`'s output rows here as
    /// they arrive off the row channel). Reused across batches.
    stage_bufs: Vec<SpikeFrame>,
}

impl Pipeline {
    /// Build engines for every accelerated layer. `sources` supplies
    /// weights per *conv/fc* layer in order (pool layers take none).
    ///
    /// Prefer constructing through `sti_snn::session::Session` — this
    /// constructor is the facade's internal building block, kept
    /// public for tests and custom engine wiring.
    pub fn new(net: NetworkSpec, config: PipelineConfig,
               sources: Vec<LayerWeights>) -> anyhow::Result<Self> {
        let cfg = EngineConfig {
            timing: config.timing,
            timesteps: config.timesteps,
            backend: config.backend,
            intra_parallel: config.intra_parallel,
        };
        let engines = build_engines(&net, &cfg, sources)?;
        Ok(Self::from_engines(net, config, engines))
    }

    /// Assemble a pipeline from pre-built engines (the trait-level
    /// constructor: any [`LayerEngine`] impls, in layer order).
    pub fn from_engines(net: NetworkSpec, config: PipelineConfig,
                        mut engines: Vec<Box<dyn LayerEngine>>) -> Self {
        for eng in engines.iter_mut() {
            eng.set_trace(config.trace.clone());
        }
        let codecs = engines.iter().map(|e| e.event_codec()).collect();
        let bufs: Vec<_> =
            engines.iter().map(|_| SpikeFrame::zeros(0, 0, 0)).collect();
        let stage_bufs =
            engines.iter().map(|_| SpikeFrame::zeros(0, 0, 0)).collect();
        Self { net, config, engines, codecs, bufs, stage_bufs }
    }

    /// Convenience: random weights everywhere (hardware experiments).
    pub fn random(net: NetworkSpec, config: PipelineConfig)
                  -> anyhow::Result<Self> {
        let sources = random_sources(&net, 1000);
        Self::new(net, config, sources)
    }

    /// Run a batch of (already spike-encoded) frames.
    ///
    /// Frames enter at the first accelerated layer: for nets with an
    /// encoder conv, the caller supplies the encoder's output spikes
    /// (from the PJRT runtime or a synthetic generator).
    ///
    /// With `pipelined` on (and more than one layer) the batch runs on
    /// the streamed schedule — one worker per layer, bounded row
    /// channels between them; otherwise layers run serially per frame.
    /// Both schedules produce bit-identical reports.
    ///
    /// If the streamed schedule fails — a layer worker panics, a
    /// watchdog deadline expires, or a channel closes mid-frame — the
    /// batch is retried once on the serial schedule (still
    /// bit-identical: `total_cycles` follows `config.pipelined`, not
    /// the schedule that happened to execute). With
    /// `watchdog.retry_serial == false` the failure escalates as a
    /// panic instead, which a supervised replica worker catches and
    /// converts into an error reply.
    pub fn run(&mut self, frames: &[SpikeFrame]) -> PipelineReport {
        assert!(!frames.is_empty(), "empty batch");
        // Streamed execution needs every non-terminal layer to expose
        // an output frame shape (the classifier head needs none — it
        // is last).
        let n = self.engines.len();
        let streamable = self.config.pipelined
            && n > 1
            && self.engines[..n - 1].iter().all(|e| e.out_shape().is_some());
        if streamable {
            match self.run_streamed(frames) {
                Ok(report) => report,
                Err(cause) => self.recover_serial(frames, &cause),
            }
        } else {
            self.run_serial(frames)
        }
    }

    /// Graceful degradation after a streamed-schedule failure: count
    /// the fire, leave a "fault" trace span, and re-run the batch
    /// serially (the channels and scoped workers of the failed attempt
    /// are already torn down — `run_streamed` owns nothing persistent
    /// beyond the reusable frame buffers, which `run_serial` resets).
    fn recover_serial(&mut self, frames: &[SpikeFrame], cause: &str)
                      -> PipelineReport {
        if let Some(stats) = &self.config.supervise {
            stats.watchdog_fires.fetch_add(1, Ordering::SeqCst);
        }
        if let Some(tr) = self.config.trace.as_deref() {
            let t0 = tr.start();
            tr.record("watchdog.fire", "fault", t0,
                      [("frames", frames.len() as u64), ("", 0)]);
        }
        let retry = self
            .config
            .watchdog
            .map(|w| w.retry_serial)
            .unwrap_or(true);
        if !retry {
            panic!("streamed schedule failed ({cause}) and serial \
                    retry is disabled");
        }
        self.run_serial(frames)
    }

    /// The serial schedule: per frame, layers run one after another
    /// through the reusable activation buffers. This is the
    /// zero-allocation reference path (`tests/alloc_budget.rs`).
    fn run_serial(&mut self, frames: &[SpikeFrame]) -> PipelineReport {
        let mut layer_cycles = vec![0u64; self.engines.len()];
        let mut layer_names = vec![String::new(); self.engines.len()];
        let mut layer_energy = vec![EnergyReport::default();
                                    self.engines.len()];
        let mut layer_vmem = vec![0usize; self.engines.len()];
        let mut counters = AccessCounter::new();
        let mut ops_total = 0u64;
        let mut codec_ratios = Vec::new();
        let mut predictions = Vec::new();
        let mut logits_all = Vec::new();

        let n_engines = self.engines.len();
        let engines = &mut self.engines;
        let bufs = &mut self.bufs;
        let codecs = &self.codecs;
        let energy = &self.config.energy;
        let trace = self.config.trace.as_deref();
        for (fi, frame) in frames.iter().enumerate() {
            let frame_t0 = trace.map(|t| t.start());
            for li in 0..n_engines {
                // Zero-copy chaining: engine li reads the previous
                // layer's reusable buffer and writes its own.
                let (prev, cur) = bufs.split_at_mut(li);
                let input: &SpikeFrame =
                    if li == 0 { frame } else { &prev[li - 1] };
                let eng = &mut engines[li];
                if fi == 0 {
                    layer_names[li] = format!("{}{li}{}", eng.kind(),
                                              eng.label_detail());
                    // Inter-layer event stream accounting (first frame
                    // only — ratios are representative).
                    if let Some(codec) = &codecs[li] {
                        codec_ratios.push(codec.stats(input).ratio());
                    }
                }
                let off_chip = li == 0;
                let layer_t0 = trace.map(|t| t.start());
                let (res, step) =
                    eng.process_frame_into(input, off_chip, &mut cur[0]);
                if let (Some(tr), Some(t0)) = (trace, layer_t0) {
                    tr.record("layer", "serial", t0,
                              [("layer", li as u64),
                               ("frame", fi as u64)]);
                }
                if fi == 0 {
                    layer_cycles[li] = step.cycles;
                    layer_energy[li] = energy.dynamic(step.ops,
                                                      &step.counters);
                    layer_vmem[li] = eng.vmem_bytes();
                }
                ops_total += step.ops;
                counters.merge(&step.counters);
                if let LayerResult::Classified { class, logits } = res {
                    predictions.push(class);
                    logits_all.push(logits);
                }
            }
            if let (Some(tr), Some(t0)) = (trace, frame_t0) {
                tr.record("frame", "serial", t0,
                          [("frame", fi as u64), ("", 0)]);
            }
        }

        self.finish_report(frames.len() as u64, layer_cycles, layer_names,
                           ops_total, counters, layer_energy, layer_vmem,
                           codec_ratios, predictions, logits_all,
                           Vec::new())
    }

    /// The streamed schedule (the executed Fig. 9): one scoped worker
    /// thread per layer; worker `i` forwards each completed output row
    /// over a bounded [`row_channel`] and worker `i+1` stages arrived
    /// rows into its input frame, starting its own output rows as soon
    /// as a kernel-height window is resident — `Kh`-row latency per
    /// link, the overlap Eq. (10) models. Composes with intra-frame
    /// bands (`intra_parallel`): bands run inside a layer worker, so
    /// parallelism is rows x layers.
    ///
    /// Bit-exactness: every charge flows through the same engine row
    /// routines as the serial schedule; per-layer tallies are merged
    /// in layer order after the scope joins, so all report fields are
    /// identical to [`Pipeline::run_serial`].
    ///
    /// Fallible: `Err` carries the first failure cause (worker panic,
    /// watchdog fire, or channel closure). Failures tear down cleanly
    /// — a worker that errors drops its channel ends, which unblocks
    /// its neighbours (their blocking receive/acquire observes the
    /// disconnect), so every scoped thread joins.
    fn run_streamed(&mut self, frames: &[SpikeFrame])
                    -> Result<PipelineReport, String> {
        let n_engines = self.engines.len();
        let out_shapes: Vec<Option<(usize, usize, usize)>> =
            self.engines.iter().map(|e| e.out_shape()).collect();

        // Link i carries engine i's output rows to engine i+1. The
        // bound is enforced by `capacity` circulating row buffers.
        let cap = self.config.channel_capacity.max(1);
        let trace = &self.config.trace;
        let mut rxs: Vec<Option<RowReceiver>> = vec![None];
        let mut txs: Vec<Option<RowSender>> =
            Vec::with_capacity(n_engines);
        let mut link_stats = Vec::with_capacity(n_engines - 1);
        for (li, shape) in
            out_shapes.iter().take(n_engines - 1).enumerate()
        {
            let (_, w, c) = shape.expect("checked streamable");
            let (mut tx, rx) = row_channel(cap, (w * c).div_ceil(64));
            tx.set_trace(trace.clone(), li as u64);
            link_stats.push(tx.stats());
            txs.push(Some(tx));
            rxs.push(Some(rx));
        }
        txs.push(None);

        let engines = &mut self.engines;
        let bufs = &mut self.bufs;
        let stage_bufs = &mut self.stage_bufs;
        let codecs = &self.codecs;
        let energy = &self.config.energy;
        let guard = WorkerGuard {
            aborted: Arc::new(AtomicBool::new(false)),
            policy: self.config.watchdog,
            faults: self.config.faults.clone(),
        };

        let mut tallies = Vec::with_capacity(n_engines);
        let mut failure: Option<String> = None;
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(n_engines);
            let mut rx_iter = rxs.into_iter();
            let mut tx_iter = txs.into_iter();
            let workers = engines
                .iter_mut()
                .zip(bufs.iter_mut())
                .zip(stage_bufs.iter_mut())
                .zip(codecs.iter());
            for (li, (((eng, out), stage), codec)) in workers.enumerate() {
                let rx = rx_iter.next().expect("one rx slot per worker");
                let tx = tx_iter.next().expect("one tx slot per worker");
                let in_shape =
                    if li == 0 { None } else { out_shapes[li - 1] };
                let trace = trace.clone();
                let guard = guard.clone();
                handles.push(s.spawn(move || {
                    stream_worker(li, eng.as_mut(), out, stage,
                                  codec.as_ref(), rx, tx, in_shape,
                                  frames, energy, trace, guard)
                }));
            }
            for h in handles {
                match h.join() {
                    Ok(Ok(t)) => tallies.push(t),
                    Ok(Err(e)) => {
                        failure.get_or_insert(e);
                    }
                    Err(payload) => {
                        failure.get_or_insert(format!(
                            "layer worker panicked: {}",
                            panic_message(payload.as_ref())));
                    }
                }
            }
        });
        if let Some(cause) = failure {
            return Err(cause);
        }
        // Satellite: surface the per-link channel counters instead of
        // dropping them with the senders.
        let channel_stats: Vec<ChannelSnapshot> =
            link_stats.iter().map(|s| s.snapshot()).collect();

        let mut layer_cycles = Vec::with_capacity(n_engines);
        let mut layer_names = Vec::with_capacity(n_engines);
        let mut layer_energy = Vec::with_capacity(n_engines);
        let mut layer_vmem = Vec::with_capacity(n_engines);
        let mut counters = AccessCounter::new();
        let mut ops_total = 0u64;
        let mut codec_ratios = Vec::new();
        let mut predictions = Vec::new();
        let mut logits_all = Vec::new();
        for t in tallies {
            layer_cycles.push(t.cycles);
            layer_names.push(t.name);
            layer_energy.push(t.energy);
            layer_vmem.push(t.vmem);
            if let Some(r) = t.codec_ratio {
                codec_ratios.push(r);
            }
            ops_total += t.ops;
            counters.merge(&t.counters);
            for (class, logits) in t.classified {
                predictions.push(class);
                logits_all.push(logits);
            }
        }
        Ok(self.finish_report(frames.len() as u64, layer_cycles,
                              layer_names, ops_total, counters,
                              layer_energy, layer_vmem, codec_ratios,
                              predictions, logits_all, channel_stats))
    }

    /// Fold per-layer tallies into the batch report (shared by both
    /// schedules — the Eq. (10) cycle model lives here).
    #[allow(clippy::too_many_arguments)]
    fn finish_report(&self, n: u64, layer_cycles: Vec<u64>,
                     layer_names: Vec<String>, ops_total: u64,
                     counters: AccessCounter,
                     layer_energy: Vec<EnergyReport>,
                     layer_vmem: Vec<usize>, codec_ratios: Vec<f64>,
                     predictions: Vec<usize>, logits: Vec<Vec<f32>>,
                     channel_stats: Vec<ChannelSnapshot>)
                     -> PipelineReport {
        let t_max = layer_cycles.iter().copied().max().unwrap_or(0);
        let t_sum: u64 = layer_cycles.iter().sum();
        // Eq. (10) when pipelined; pure serialisation otherwise.
        let total_cycles = if self.config.pipelined {
            n * t_max + (t_sum - t_max)
        } else {
            n * t_sum
        };

        let resources = self
            .config
            .resources
            .network(&self.net, self.config.timesteps);

        PipelineReport {
            frames: n,
            layer_cycles,
            layer_names,
            t_max,
            t_sum,
            total_cycles,
            ops_per_frame: ops_total / n,
            counters,
            layer_energy,
            layer_vmem_bytes: layer_vmem,
            codec_ratios,
            predictions,
            logits,
            resources,
            pes: self.net.total_pes(),
            channel_stats,
        }
    }

    /// Shape of the frames this pipeline expects (post-encoder;
    /// delegates to [`NetworkSpec::accel_input_shape`]).
    pub fn input_shape(&self) -> (usize, usize, usize) {
        self.net.accel_input_shape()
    }
}

/// Everything one layer worker accumulates over a batch — merged into
/// the report in layer order after the scope joins, making the
/// streamed report deterministic and identical to the serial one.
struct LayerTally {
    name: String,
    /// One-frame cycles (frame 0 — identical every frame).
    cycles: u64,
    energy: EnergyReport,
    vmem: usize,
    codec_ratio: Option<f64>,
    ops: u64,
    counters: AccessCounter,
    /// Classifier outputs in frame order (classifier layers only).
    classified: Vec<(usize, Vec<f32>)>,
}

/// Shared failure-detection state for one streamed batch: the abort
/// flag every per-frame [`Deadline`] arms against (one worker firing
/// aborts all of them), the watchdog policy, and the fault-injection
/// hooks (both `None`/off in production).
#[derive(Clone)]
struct WorkerGuard {
    aborted: Arc<AtomicBool>,
    policy: Option<WatchdogPolicy>,
    faults: Option<Arc<FaultHooks>>,
}

/// Body of one layer worker thread of the streamed schedule.
///
/// Per frame: receive input rows (worker 0 reads the batch frame
/// directly; later workers stage rows arriving off `rx`), hand each to
/// the engine's row entry point, and forward every completed output
/// row over `tx`. A buffer is recycled *before* the row is processed,
/// so the consumer never holds more than one in-flight buffer — with
/// the acyclic worker chain that makes any channel capacity >= 1
/// deadlock-free.
///
/// With a watchdog armed, every blocking channel wait is sliced
/// against a per-frame [`Deadline`]; an overdue frame (or a deadline
/// fired by any sibling worker) makes the worker return `Err`, which
/// drops its channel ends and cascades the teardown. Without a
/// watchdog the plain blocking waits run — zero supervision overhead —
/// and a hung-up channel (sibling panic) is the only error path.
#[allow(clippy::too_many_arguments)]
fn stream_worker(li: usize, eng: &mut dyn LayerEngine,
                 out: &mut SpikeFrame, stage: &mut SpikeFrame,
                 codec: Option<&EventCodec>, rx: Option<RowReceiver>,
                 tx: Option<RowSender>,
                 in_shape: Option<(usize, usize, usize)>,
                 frames: &[SpikeFrame], energy: &EnergyModel,
                 trace: Option<Arc<TraceSink>>, guard: WorkerGuard)
                 -> Result<LayerTally, String> {
    let mut tally = LayerTally {
        name: format!("{}{li}{}", eng.kind(), eng.label_detail()),
        cycles: 0,
        energy: EnergyReport::default(),
        vmem: 0,
        codec_ratio: None,
        ops: 0,
        counters: AccessCounter::new(),
        classified: Vec::new(),
    };
    for (fi, frame) in frames.iter().enumerate() {
        // One span per (layer, frame) on this worker's own thread
        // track — the inter-layer overlap is directly visible as
        // overlapping spans across tracks in the exported trace.
        let t0 = trace.as_ref().map(|t| t.start());
        let deadline = guard
            .policy
            .map(|p| Deadline::arm(p.deadline, guard.aborted.clone()));
        // Injected channel stall: the worker sleeps here, its
        // neighbours back up, and (with a watchdog armed) one of them
        // fires the shared deadline.
        if let Some(ms) =
            guard.faults.as_ref().and_then(|f| f.stall(li))
        {
            std::thread::sleep(ms);
        }
        if let Some((h, w, c)) = eng.out_shape() {
            out.reset(h, w, c);
        }
        eng.begin_frame(li == 0);
        let mut sent = 0usize;
        if let Some(rx) = &rx {
            let (h, w, c) = in_shape.expect("upstream shape known");
            stage.reset(h, w, c);
            for y in 0..h {
                let buf = recv_row(rx, deadline.as_ref())?;
                stage.or_row_words(y, &buf);
                // Recycle before computing: progress at any capacity.
                rx.recycle(buf);
                let done = eng.process_row_into(stage, y, out);
                forward_rows(&tx, out, &mut sent, done,
                             deadline.as_ref())?;
            }
        } else {
            for y in 0..frame.h {
                let done = eng.process_row_into(frame, y, out);
                forward_rows(&tx, out, &mut sent, done,
                             deadline.as_ref())?;
            }
        }
        let input: &SpikeFrame =
            if rx.is_some() { &*stage } else { frame };
        if fi == 0 {
            // Inter-layer event stream accounting (first frame only —
            // ratios are representative). The serial schedule computes
            // this on the same fully-assembled input frame.
            if let Some(codec) = codec {
                tally.codec_ratio = Some(codec.stats(input).ratio());
            }
        }
        let (res, step) = eng.finish_frame(input, out);
        forward_rows(&tx, out, &mut sent, out.h, deadline.as_ref())?;
        if fi == 0 {
            tally.cycles = step.cycles;
            tally.energy = energy.dynamic(step.ops, &step.counters);
            tally.vmem = eng.vmem_bytes();
        }
        tally.ops += step.ops;
        tally.counters.merge(&step.counters);
        if let LayerResult::Classified { class, logits } = res {
            tally.classified.push((class, logits));
        }
        if let (Some(tr), Some(t0)) = (trace.as_ref(), t0) {
            tr.record("stream.layer", "stream", t0,
                      [("layer", li as u64), ("frame", fi as u64)]);
        }
    }
    Ok(tally)
}

/// Receive one upstream row, slicing the wait against the frame
/// deadline when a watchdog is armed.
fn recv_row(rx: &RowReceiver, deadline: Option<&Deadline>)
            -> Result<Vec<u64>, String> {
    let Some(d) = deadline else {
        return rx
            .recv()
            .ok_or_else(|| "upstream worker hung up mid-frame".into());
    };
    loop {
        if d.expired() {
            d.fire();
            return Err("watchdog deadline exceeded waiting on \
                        upstream rows"
                .into());
        }
        match rx.recv_timeout(d.wait_slice(WATCHDOG_SLICE)) {
            RowWait::Ready(buf) => return Ok(buf),
            RowWait::TimedOut => continue,
            RowWait::Closed => {
                return Err("upstream worker hung up mid-frame".into())
            }
        }
    }
}

/// Forward output rows `[*sent, done)` downstream as word-packed row
/// payloads, blocking on channel backpressure (deadline-sliced when a
/// watchdog is armed).
fn forward_rows(tx: &Option<RowSender>, out: &SpikeFrame,
                sent: &mut usize, done: usize,
                deadline: Option<&Deadline>) -> Result<(), String> {
    let Some(tx) = tx else { return Ok(()) };
    let done = done.min(out.h);
    while *sent < done {
        let mut buf = acquire_row(tx, deadline)?;
        out.row_words_into(*sent, &mut buf);
        tx.send(buf);
        *sent += 1;
    }
    Ok(())
}

/// Acquire one downstream row buffer, slicing the wait against the
/// frame deadline when a watchdog is armed. Only the first timed-out
/// slice counts as a backpressure wait, so channel stats stay
/// comparable with the unsupervised blocking path.
fn acquire_row(tx: &RowSender, deadline: Option<&Deadline>)
               -> Result<Vec<u64>, String> {
    let Some(d) = deadline else {
        return tx
            .acquire()
            .ok_or_else(|| "downstream worker hung up".into());
    };
    let mut first = true;
    loop {
        if d.expired() {
            d.fire();
            return Err("watchdog deadline exceeded waiting on \
                        downstream credit"
                .into());
        }
        match tx.acquire_timeout(d.wait_slice(WATCHDOG_SLICE), first) {
            RowWait::Ready(buf) => return Ok(buf),
            RowWait::TimedOut => first = false,
            RowWait::Closed => {
                return Err("downstream worker hung up".into())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{scnn3, scnn5, vmobilenet};
    use crate::util::rng::Rng;

    fn frames(shape: (usize, usize, usize), n: usize, rate: f64)
              -> Vec<SpikeFrame> {
        let mut rng = Rng::new(99);
        (0..n)
            .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, rate,
                                        &mut rng))
            .collect()
    }

    #[test]
    fn scnn3_pipeline_runs() {
        let net = scnn3();
        let mut p = Pipeline::random(net, PipelineConfig::default()).unwrap();
        let shape = p.input_shape();
        assert_eq!(shape, (28, 28, 16)); // post-encoder
        let rep = p.run(&frames(shape, 2, 0.2));
        assert_eq!(rep.predictions.len(), 2);
        assert!(rep.t_max > 0);
        assert!(rep.ops_per_frame > 0);
    }

    #[test]
    fn pipelining_beats_serial() {
        let net = scnn3();
        let f = frames((28, 28, 16), 4, 0.2);
        let mut pipe = Pipeline::random(net.clone(),
                                        PipelineConfig::default()).unwrap();
        let r_pipe = pipe.run(&f);
        let mut serial = Pipeline::random(
            net,
            PipelineConfig { pipelined: false, ..Default::default() },
        )
        .unwrap();
        let r_serial = serial.run(&f);
        assert!(r_pipe.total_cycles < r_serial.total_cycles);
        // Functional results identical.
        assert_eq!(r_pipe.predictions, r_serial.predictions);
    }

    #[test]
    fn pipeline_matches_analytical_model() {
        let net = scnn3();
        let mut p = Pipeline::random(net.clone(),
                                     PipelineConfig::default()).unwrap();
        let rep = p.run(&frames((28, 28, 16), 1, 0.2));
        let model = crate::dataflow::pipeline_latency(
            &net, &ConvLatencyParams::optimized(), 1);
        // Engine t_max within 5% of Eq. (12) prediction.
        let err = (rep.t_max as f64 - model.t_max as f64).abs()
            / model.t_max as f64;
        assert!(err < 0.05, "engine {} model {}", rep.t_max, model.t_max);
    }

    #[test]
    fn vmobilenet_dsc_modes_run() {
        let net = vmobilenet();
        let mut p = Pipeline::random(net, PipelineConfig::default()).unwrap();
        let shape = p.input_shape();
        assert_eq!(shape, (28, 28, 16));
        let rep = p.run(&frames(shape, 1, 0.3));
        assert_eq!(rep.predictions.len(), 1);
        // 8 DSC layers + fc accounted.
        assert!(rep.layer_cycles.iter().filter(|&&c| c > 0).count() >= 9);
    }

    #[test]
    fn t1_frees_vmem_and_halves_energy_vs_t2() {
        // Scaled-down SCNN5 geometry keeps the test fast.
        let net = crate::arch::NetBuilder::new("mini5", (16, 16, 3))
            .encoder(8, 3)
            .pool()
            .conv(16, 3)
            .pool()
            .conv(32, 3)
            .pool()
            .fc(10)
            .build();
        let mut p1 = Pipeline::random(net.clone(),
                                      PipelineConfig::default()).unwrap();
        let f = frames(p1.input_shape(), 1, 0.25);
        let r1 = p1.run(&f);
        let mut p2 = Pipeline::random(
            net,
            PipelineConfig { timesteps: 2, ..Default::default() },
        )
        .unwrap();
        let r2 = p2.run(&f);
        // Fig. 11: no Vmem at T1, real Vmem at T2.
        assert!(r1.layer_vmem_bytes.iter().all(|&b| b == 0));
        assert!(r2.layer_vmem_bytes.iter().any(|&b| b > 0));
        // Energy roughly doubles with T.
        let e1 = r1.dynamic_energy_per_frame_j();
        let e2 = r2.dynamic_energy_per_frame_j();
        let ratio = e2 / e1;
        assert!(ratio > 1.8 && ratio < 2.4, "ratio {ratio}");
    }

    #[test]
    fn parallel_factors_speed_up_scnn5() {
        // Tiny frame count; scnn5 geometry is the real one so this is
        // the slowest test — keep N = 1.
        let mut base = Pipeline::random(scnn5(),
                                        PipelineConfig::default()).unwrap();
        let f = frames(base.input_shape(), 1, 0.15);
        let r_base = base.run(&f);
        let mut par = Pipeline::random(
            scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap(),
            PipelineConfig::default(),
        )
        .unwrap();
        let r_par = par.run(&f);
        let speedup = r_base.t_max as f64 / r_par.t_max as f64;
        assert!(speedup > 3.0, "speedup {speedup}");
        assert_eq!(r_par.pes, 99);
    }

    /// The word-parallel backend changes host speed only: predictions,
    /// logits, cycle totals, op counts and traffic are all identical.
    #[test]
    fn word_parallel_pipeline_is_bit_exact() {
        let net = scnn3();
        let f = frames((28, 28, 16), 2, 0.2);
        let mut acc = Pipeline::random(net.clone(),
                                       PipelineConfig::default()).unwrap();
        let mut wp = Pipeline::random(
            net,
            PipelineConfig {
                backend: BackendKind::WordParallel,
                ..Default::default()
            },
        )
        .unwrap();
        let ra = acc.run(&f);
        let rw = wp.run(&f);
        assert_eq!(ra.predictions, rw.predictions);
        assert_eq!(ra.logits, rw.logits);
        assert_eq!(ra.total_cycles, rw.total_cycles);
        assert_eq!(ra.ops_per_frame, rw.ops_per_frame);
        assert_eq!(ra.counters, rw.counters);
    }

    /// Intra-frame row bands change host speed only: the whole
    /// pipeline report is bit-identical across band counts.
    #[test]
    fn intra_parallel_pipeline_is_bit_exact() {
        let net = scnn3();
        let f = frames((28, 28, 16), 2, 0.2);
        let mut serial = Pipeline::random(net.clone(),
                                          PipelineConfig::default())
            .unwrap();
        let rs = serial.run(&f);
        for bands in [2, 4] {
            let mut banded = Pipeline::random(
                net.clone(),
                PipelineConfig {
                    intra_parallel: bands,
                    backend: BackendKind::WordParallel,
                    ..Default::default()
                },
            )
            .unwrap();
            let rb = banded.run(&f);
            assert_eq!(rs.predictions, rb.predictions, "bands={bands}");
            assert_eq!(rs.logits, rb.logits, "bands={bands}");
            assert_eq!(rs.total_cycles, rb.total_cycles, "bands={bands}");
            assert_eq!(rs.layer_cycles, rb.layer_cycles, "bands={bands}");
            assert_eq!(rs.ops_per_frame, rb.ops_per_frame,
                       "bands={bands}");
            assert_eq!(rs.counters, rb.counters, "bands={bands}");
        }
    }

    /// Reusable activation buffers do not leak state between frames:
    /// running the same batch twice reproduces the first report.
    #[test]
    fn repeated_batches_are_deterministic() {
        let net = scnn3();
        let f = frames((28, 28, 16), 2, 0.2);
        let mut p = Pipeline::random(net, PipelineConfig::default())
            .unwrap();
        let r1 = p.run(&f);
        let r2 = p.run(&f);
        assert_eq!(r1.predictions, r2.predictions);
        assert_eq!(r1.logits, r2.logits);
        assert_eq!(r1.total_cycles, r2.total_cycles);
        assert_eq!(r1.counters, r2.counters);
    }

    /// Satellite: the streamed schedule surfaces one channel-stat
    /// snapshot per inter-layer link (every row was sent), and the
    /// serial schedule reports none.
    #[test]
    fn streamed_schedule_reports_channel_stats() {
        let net = scnn3();
        let f = frames((28, 28, 16), 2, 0.2);
        let mut p = Pipeline::random(net.clone(),
                                     PipelineConfig::default()).unwrap();
        let rep = p.run(&f);
        // 5 engines => 4 links.
        assert_eq!(rep.channel_stats.len(), 4);
        for (li, cs) in rep.channel_stats.iter().enumerate() {
            assert!(cs.sends > 0, "link {li} sent nothing");
            assert_eq!(cs.sends, cs.recvs, "link {li} lost rows");
            assert!(cs.max_occupancy <= 4, "link {li} over capacity");
        }
        let mut serial = Pipeline::random(
            net,
            PipelineConfig { pipelined: false, ..Default::default() },
        )
        .unwrap();
        assert!(serial.run(&f).channel_stats.is_empty());
    }

    /// Tracing records worker spans per (layer, frame) on both
    /// schedules without touching any architectural report field.
    #[test]
    fn trace_sink_records_spans_reports_unchanged() {
        let net = scnn3();
        let f = frames((28, 28, 16), 2, 0.2);
        let mut plain = Pipeline::random(net.clone(),
                                         PipelineConfig::default())
            .unwrap();
        let want = plain.run(&f);

        let sink = Arc::new(crate::telemetry::TraceSink::new(1 << 14));
        let mut traced = Pipeline::random(
            net,
            PipelineConfig { trace: Some(sink.clone()),
                             ..Default::default() },
        )
        .unwrap();
        let got = traced.run(&f);
        assert_eq!(want.predictions, got.predictions);
        assert_eq!(want.logits, got.logits);
        assert_eq!(want.total_cycles, got.total_cycles);
        assert_eq!(want.counters, got.counters);

        let evs = sink.events();
        assert!(!evs.is_empty());
        // Every (layer, frame) pair got a streamed worker span.
        for li in 0..5u64 {
            for fi in 0..2u64 {
                assert!(evs.iter().any(|e| e.name == "stream.layer"
                            && e.args == [("layer", li), ("frame", fi)]),
                        "missing span layer={li} frame={fi}");
            }
        }
        // Conv band spans rode along from inside the engines.
        assert!(evs.iter().any(|e| e.name == "conv.row"));
    }

    #[test]
    fn event_codec_ratios_reported() {
        let net = scnn3();
        let mut p = Pipeline::random(net, PipelineConfig::default()).unwrap();
        let rep = p.run(&frames((28, 28, 16), 1, 0.05));
        assert!(!rep.codec_ratios.is_empty());
        // Sparse input -> first link compresses.
        assert!(rep.codec_ratios[0] > 1.0);
    }

    use crate::supervise::{FaultEvent, FaultPlan};

    /// A stalled layer worker trips the watchdog; the batch recovers
    /// on the serial schedule with a bit-identical report (channel
    /// stats excepted — the recovered run has none).
    #[test]
    fn watchdog_recovers_stalled_stream_serially() {
        let net = scnn3();
        let f = frames((28, 28, 16), 2, 0.2);
        let mut plain = Pipeline::random(net.clone(),
                                         PipelineConfig::default())
            .unwrap();
        let want = plain.run(&f);

        let stats = Arc::new(SuperviseStats::default());
        let hooks = Arc::new(FaultHooks::from_plan(FaultPlan::new(
            7,
            vec![FaultEvent::StallChannel { layer: 1, ms: 2500 }],
        )));
        let mut guarded = Pipeline::random(
            net,
            PipelineConfig {
                watchdog: Some(WatchdogPolicy::with_deadline_ms(250)),
                faults: Some(hooks.clone()),
                supervise: Some(stats.clone()),
                ..Default::default()
            },
        )
        .unwrap();
        let got = guarded.run(&f);
        assert_eq!(stats.snapshot().watchdog_fires, 1);
        assert_eq!(hooks.injected(), 1);
        assert_eq!(want.predictions, got.predictions);
        assert_eq!(want.logits, got.logits);
        assert_eq!(want.total_cycles, got.total_cycles);
        assert_eq!(want.layer_cycles, got.layer_cycles);
        assert_eq!(want.ops_per_frame, got.ops_per_frame);
        assert_eq!(want.counters, got.counters);
        assert!(got.channel_stats.is_empty(),
                "recovered run executed serially");
        // The pipeline stays healthy after recovery: the stall was a
        // one-shot fault, so the next batch streams normally.
        let again = guarded.run(&f);
        assert_eq!(want.predictions, again.predictions);
        assert_eq!(stats.snapshot().watchdog_fires, 1);
        assert!(!again.channel_stats.is_empty());
    }

    /// An idle watchdog (no fault, generous deadline) changes nothing:
    /// the deadline-sliced channel waits are still bit-exact and no
    /// fire is recorded.
    #[test]
    fn idle_watchdog_leaves_report_unchanged() {
        let net = scnn3();
        let f = frames((28, 28, 16), 2, 0.2);
        let mut plain = Pipeline::random(net.clone(),
                                         PipelineConfig::default())
            .unwrap();
        let want = plain.run(&f);
        let stats = Arc::new(SuperviseStats::default());
        let mut guarded = Pipeline::random(
            net,
            PipelineConfig {
                watchdog: Some(WatchdogPolicy::default()),
                supervise: Some(stats.clone()),
                ..Default::default()
            },
        )
        .unwrap();
        let got = guarded.run(&f);
        assert_eq!(want.predictions, got.predictions);
        assert_eq!(want.logits, got.logits);
        assert_eq!(want.total_cycles, got.total_cycles);
        assert_eq!(want.counters, got.counters);
        assert_eq!(stats.snapshot().watchdog_fires, 0);
        assert!(!got.channel_stats.is_empty(), "still streamed");
    }

    /// With serial retry disabled the failure escalates as a panic —
    /// the supervised replica worker upstream catches it and converts
    /// it into an error reply.
    #[test]
    fn watchdog_without_retry_escalates() {
        let net = scnn3();
        let f = frames((28, 28, 16), 1, 0.2);
        let hooks = Arc::new(FaultHooks::from_plan(FaultPlan::new(
            7,
            vec![FaultEvent::StallChannel { layer: 1, ms: 1500 }],
        )));
        let mut p = Pipeline::random(
            net,
            PipelineConfig {
                watchdog: Some(WatchdogPolicy {
                    deadline: Duration::from_millis(150),
                    retry_serial: false,
                }),
                faults: Some(hooks),
                ..Default::default()
            },
        )
        .unwrap();
        let err = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| p.run(&f)))
            .unwrap_err();
        let msg = crate::supervise::panic_message(err.as_ref());
        assert!(msg.contains("serial retry is disabled"), "{msg}");
    }
}
