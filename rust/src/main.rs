//! sti-snn CLI: run the accelerator simulator, regenerate the paper's
//! tables/figures, serve inference.
//!
//! Every subcommand constructs the simulator stack through the
//! `sti_snn::session` facade (one builder for network, weights, design
//! point, replicas, and auto-tuning).
//!
//! Subcommands (each maps to a paper artifact — DESIGN.md experiment
//! index):
//!   table1   — OS vs WS memory-access counts (paper Table I)
//!   table3   — per-conv-mode access counts (paper Table III)
//!   table4   — FPS/GOPS/W/efficiency design points (paper Table IV)
//!   table5   — resource utilisation (paper Table V)
//!   fig11    — SCNN5 per-layer Vmem + energy, T1 vs T2 (paper Fig. 11)
//!   fig12    — SCNN5 delay/power/LUT/FF before/after parallelism
//!   optimize — parallel-factor scheduler for a PE budget
//!   explore  — design-space exploration (Pareto frontier + report)
//!   run      — run frames through a model's pipeline (sim); with
//!              --events, stream a DVS-style event file (or synth)
//!              through the windowed ingestion path
//!   serve    — TCP inference server (artifacts required; --synthetic
//!              and --auto-tune need none); --events bounds the queue
//!              for event-streaming backpressure
//!   gen-events — write a synthetic DVS-like .aer event file for load
//!              testing the events paths

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Context as _;

use sti_snn::arch;
use sti_snn::autotune::RetunePolicy;
use sti_snn::codec::stream::{self, DvsEvent, WindowPolicy};
use sti_snn::codec::SpikeFrame;
use sti_snn::coordinator::scheduler;
use sti_snn::dataflow::{self, ConvLatencyParams};
use sti_snn::dse;
use sti_snn::metrics::PerfRow;
use sti_snn::model::Artifact;
use sti_snn::runtime::{artifacts_dir, Runtime};
use sti_snn::server::{Backend, Server};
use sti_snn::session::{Session, Weights};
use sti_snn::sim::{cycles_to_ms, BackendKind, EnergyModel,
                   ResourceModel};
use sti_snn::supervise::{FaultPlan, WatchdogPolicy};
use sti_snn::telemetry::{TraceSink, DEFAULT_TRACE_CAPACITY};
use sti_snn::util::cli::Args;
use sti_snn::util::rng::Rng;

fn usage() {
    eprintln!(
        "usage: sti-snn <subcommand> [flags]\n\
         \n\
         subcommands:\n\
         \x20 table1   OS vs WS memory-access counts (paper Table I)\n\
         \x20 table3   per-conv-mode access counts (paper Table III)\n\
         \x20 table4   FPS/GOPS/W/efficiency design points (Table IV)\n\
         \x20 table5   resource utilisation (paper Table V)\n\
         \x20 fig11    SCNN5 per-layer Vmem + energy, T1 vs T2\n\
         \x20 fig12    SCNN5 delay/power/LUT/FF with parallelism\n\
         \x20 optimize parallel-factor scheduler for a PE budget\n\
         \x20 explore  design-space exploration: enumerate array\n\
         \x20          shapes / replicas / backends, print the Pareto\n\
         \x20          frontier, write a JSON report\n\
         \x20 run      run frames through a model's pipeline (sim)\n\
         \x20 serve    TCP inference server\n\
         \x20 gen-events  write a synthetic DVS-like event file\n\
         \x20 help     this text\n\
         \n\
         session flags (the one construction surface — every flag maps\n\
         to a sti_snn::session::SessionBuilder knob):\n\
         \x20 flag                 applies to        meaning\n\
         \x20 --model NAME         all               scnn3|scnn5|vmobilenet\n\
         \x20 --backend KIND       run/serve         functional compute\n\
         \x20                                        backend: accurate\n\
         \x20                                        (default),\n\
         \x20                                        word-parallel (fast\n\
         \x20                                        bit-plane popcount),\n\
         \x20                                        or sparse (popcount\n\
         \x20                                        with occupancy\n\
         \x20                                        skipping + batched\n\
         \x20                                        rows; fastest at\n\
         \x20                                        real spike density).\n\
         \x20                                        All bit-exact,\n\
         \x20                                        identical reports.\n\
         \x20                                        With --auto-tune,\n\
         \x20                                        pins the backend\n\
         \x20                                        choice.\n\
         \x20 --replicas N         serve             pipeline replicas\n\
         \x20                                        draining one queue\n\
         \x20                                        (default 1). With\n\
         \x20                                        --auto-tune, pins\n\
         \x20                                        the replica split.\n\
         \x20 --auto-tune          serve             calibrate + explore\n\
         \x20                                        first (implies\n\
         \x20                                        --synthetic), boot\n\
         \x20                                        the winning factors/\n\
         \x20                                        replicas/backend\n\
         \x20 --intra-parallel N   run/serve/explore intra-frame row\n\
         \x20                                        bands per conv\n\
         \x20                                        engine (scoped\n\
         \x20                                        threads; bit-exact\n\
         \x20                                        reports; default 1)\n\
         \x20 --no-pipelined       run/serve/explore serial layer\n\
         \x20                                        schedule: run each\n\
         \x20                                        layer to completion\n\
         \x20                                        instead of\n\
         \x20                                        streaming rows\n\
         \x20                                        between layer\n\
         \x20                                        workers (bit-exact\n\
         \x20                                        reports; default\n\
         \x20                                        is pipelined)\n\
         \x20 --timesteps T        all               inference timesteps\n\
         \x20                                        (default 1)\n\
         \x20 --frames N           run/table4/figs   frames per run\n\
         \x20 --rate R             run/table4/figs   synthetic input\n\
         \x20                                        firing rate\n\
         \x20 --trace PATH         run               record frame/layer/\n\
         \x20                                        band/backpressure\n\
         \x20                                        spans and write a\n\
         \x20                                        Chrome trace-event\n\
         \x20                                        JSON (open in\n\
         \x20                                        ui.perfetto.dev);\n\
         \x20                                        reports stay\n\
         \x20                                        bit-identical\n\
         \n\
         event-streaming flags (the paper's native workload shape —\n\
         sorted (x, y, c, t) address events windowed into\n\
         single-timestep frames; 12-byte LE records, see\n\
         docs/ARCHITECTURE.md):\n\
         \x20 --events PATH|synth  run               stream an .aer\n\
         \x20                                        event file (or a\n\
         \x20                                        synthetic stream)\n\
         \x20                                        through the\n\
         \x20                                        windowed ingestion\n\
         \x20                                        path\n\
         \x20 --events             serve             announce events\n\
         \x20                                        mode and bound the\n\
         \x20                                        queue (--queue-cap,\n\
         \x20                                        default 64) so\n\
         \x20                                        overload sheds\n\
         \x20                                        explicitly; needs\n\
         \x20                                        --synthetic or\n\
         \x20                                        --auto-tune (the\n\
         \x20                                        artifact backend\n\
         \x20                                        is dense-only)\n\
         \x20 --window P           run               window policy:\n\
         \x20                                        count:N or us:N\n\
         \x20                                        (default us:1000;\n\
         \x20                                        serve clients pick\n\
         \x20                                        theirs per\n\
         \x20                                        connection)\n\
         \x20 --windows N          run/gen-events    synthetic windows\n\
         \x20 --queue-cap N        serve             queue depth bound\n\
         \x20                                        (0 = unbounded)\n\
         \n\
         gen-events flags:\n\
         \x20 --out PATH           output file (default events.aer)\n\
         \x20 --model M --windows N --rate R --window-us US --seed S\n\
         \n\
         explore flags:\n\
         \x20 --pe-budget N        total PE budget across replicas\n\
         \x20                      (default 8x the unit-factor minimum)\n\
         \x20 --max-replicas N     largest replica split to consider\n\
         \x20                      (default 4)\n\
         \x20 --no-calibrate       skip the simulator calibration probes\n\
         \x20                      (use the analytical models as-is)\n\
         \x20 --report PATH        JSON report path (default\n\
         \x20                      dse_report.json)\n\
         \n\
         serve flags:\n\
         \x20 --addr HOST:PORT     bind address (default 127.0.0.1:7878)\n\
         \x20 --synthetic          serve a random-weight simulator\n\
         \x20                      pipeline (no artifacts / XLA needed);\n\
         \x20                      images are threshold-encoded at 0.5\n\
         \x20 --pe-budget N        auto-tune search budget (as explore)\n\
         \x20 --max-replicas N     auto-tune replica cap (as explore)\n\
         \x20 --max-batch N        queue drain batch size (default 16)\n\
         \x20 --max-wait-ms MS     queue wait for first item (default 5)\n\
         \x20 --online-tune        re-run the calibrated DSE against the\n\
         \x20                      measured workload on a timer and\n\
         \x20                      hot-swap the replica pool when a\n\
         \x20                      candidate clears the hysteresis\n\
         \x20                      margin (zero-downtime generation\n\
         \x20                      swap); needs --synthetic or\n\
         \x20                      --auto-tune\n\
         \x20 --retune-interval MS controller wake period (default 2000)\n\
         \x20 --retune-cooldown MS minimum time between swaps\n\
         \x20                      (default 10000)\n\
         \x20 --retune-min-frames N frames that must be observed since\n\
         \x20                      the last swap (default 32)\n\
         \x20 --retune-log PATH    write the retune event log (JSON) on\n\
         \x20                      shutdown\n\
         \x20 --watchdog-ms MS     arm a deadline watchdog over the\n\
         \x20                      streamed executor: an overdue frame\n\
         \x20                      tears the pipeline down and retries\n\
         \x20                      once on the serial schedule\n\
         \x20 --chaos PLAN.json    run under a deterministic\n\
         \x20                      fault-injection plan (panics, channel\n\
         \x20                      stalls, slow replicas, dropped\n\
         \x20                      replies); testing only — needs\n\
         \x20                      --synthetic or --auto-tune\n\
         \x20 (live metrics: send {{\"cmd\": \"metrics\"}} to a running\n\
         \x20 server for a Prometheus-style exposition — latency\n\
         \x20 quantiles, shed count, queue depth, per-layer observed\n\
         \x20 spike density; `{{\"cmd\": \"stats\"}}` returns the same\n\
         \x20 core counters as one JSON object)\n\
         \n\
         unknown flags are rejected with a nearest-flag suggestion."
    );
}

/// Per-subcommand flag vocabulary (for validation + suggestions).
fn known_flags(sub: &str) -> &'static [&'static str] {
    const COMMON: &[&str] = &["model", "timesteps"];
    match sub {
        "table1" | "table3" | "table5" => COMMON,
        "table4" | "fig11" | "fig12" => {
            &["model", "timesteps", "frames", "rate"]
        }
        "optimize" => &["model", "timesteps", "pe-budget"],
        "explore" => &["model", "timesteps", "rate", "pe-budget",
                       "max-replicas", "no-calibrate", "report",
                       "intra-parallel", "no-pipelined"],
        "run" => &["model", "timesteps", "frames", "rate", "backend",
                   "intra-parallel", "no-pipelined", "events", "window",
                   "windows", "trace"],
        "serve" => &["model", "timesteps", "rate", "backend", "addr",
                     "replicas", "synthetic", "auto-tune", "pe-budget",
                     "max-replicas", "max-batch", "max-wait-ms",
                     "intra-parallel", "no-pipelined", "events",
                     "queue-cap", "online-tune", "retune-interval",
                     "retune-cooldown", "retune-min-frames",
                     "retune-log", "watchdog-ms", "chaos"],
        "gen-events" => &["model", "out", "windows", "rate", "window-us",
                          "seed"],
        _ => COMMON,
    }
}

const SUBCOMMANDS: &[&str] = &["table1", "table3", "table4", "table5",
                               "fig11", "fig12", "optimize", "explore",
                               "run", "serve", "gen-events"];

fn main() {
    let args = Args::from_env();
    let sub = match args.subcommand.as_deref() {
        Some("help") => {
            usage();
            std::process::exit(0);
        }
        Some(s) => s.to_string(),
        None => {
            usage();
            std::process::exit(2);
        }
    };
    // Subcommand validity first, so a typoed subcommand is reported as
    // such instead of as an unknown flag of the COMMON fallback set.
    if !SUBCOMMANDS.contains(&sub.as_str()) {
        eprintln!("unknown subcommand {sub:?}\n");
        usage();
        std::process::exit(2);
    }
    if let Err(e) = args.check_known(known_flags(&sub)) {
        eprintln!("error: {e}\n");
        usage();
        std::process::exit(2);
    }
    let result = match sub.as_str() {
        "table1" => table1(&args),
        "table3" => table3(&args),
        "table4" => table4(&args),
        "table5" => table5(&args),
        "fig11" => fig11(&args),
        "fig12" => fig12(&args),
        "optimize" => optimize(&args),
        "explore" => explore(&args),
        "run" => run(&args),
        "serve" => serve(&args),
        "gen-events" => gen_events(&args),
        _ => unreachable!("subcommand validated above"),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn backend_for(args: &Args) -> anyhow::Result<Option<BackendKind>> {
    args.get_with("backend", BackendKind::parse)
        .map_err(|e| anyhow::anyhow!("{e} (accurate|word-parallel|sparse)"))
}

fn net_for(args: &Args) -> anyhow::Result<arch::NetworkSpec> {
    let name = args.get_str("model", "scnn5");
    arch::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {name}"))
}

fn synth_frames(shape: (usize, usize, usize), n: usize, rate: f64,
                seed: u64) -> Vec<SpikeFrame> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, rate,
                                    &mut rng))
        .collect()
}

// ---------------------------------------------------------------------------
// Table I
// ---------------------------------------------------------------------------

fn table1(args: &Args) -> anyhow::Result<()> {
    let net = net_for(args)?;
    let timesteps = args.get_usize("timesteps", 1) as u64;
    println!("Table I — memory access counts, OS vs WS dataflow");
    println!("model = {}, T = {timesteps}\n", net.name);
    println!("{:<10} {:>16} {:>16} {:>16} {:>16} {:>14} {:>14}",
             "layer", "OS inputs", "WS inputs", "OS weights",
             "WS weights", "OS psums", "WS psums");
    for (i, c) in net.accel_convs().iter().enumerate() {
        let os = dataflow::os_access(c, timesteps);
        let ws = dataflow::ws_access(c, timesteps);
        println!("{:<10} {:>16} {:>16} {:>16} {:>16} {:>14} {:>14}",
                 format!("conv{}", i + 1),
                 os.input_spikes, ws.input_spikes, os.weights, ws.weights,
                 os.partial_sums, ws.partial_sums);
    }
    println!("\nkey claims: OS psums = 0 at T=1; WS weight reads are \
              Wo*Ho x fewer but WS psum traffic is Ci x larger.");
    Ok(())
}

// ---------------------------------------------------------------------------
// Table III
// ---------------------------------------------------------------------------

fn table3(args: &Args) -> anyhow::Result<()> {
    let timesteps = args.get_usize("timesteps", 1) as u64;
    println!("Table III — OS + line buffer + spike vectors: vector access \
              counts per conv mode (T = {timesteps})\n");
    println!("{:<28} {:>12} {:>14} {:>12} {:>12}",
             "layer", "mode", "inputs", "weights", "psums");
    for net in [arch::scnn5(), arch::vmobilenet()] {
        for (i, c) in net.accel_convs().iter().enumerate() {
            let a = dataflow::conv_mode_access(c, timesteps);
            println!("{:<28} {:>12} {:>14} {:>12} {:>12}",
                     format!("{} conv{}", net.name, i + 1),
                     format!("{:?}", c.mode),
                     a.input_spikes, a.weights, a.partial_sums);
        }
    }
    let l = arch::scnn5().accel_convs()[0].clone();
    println!("\nline-buffer input reduction vs plain OS (SectionIV-C): {:.0}x \
              (~ Ci*Kw*Kh*Co = {})",
             dataflow::access::input_access_reduction(&l, 1),
             l.ci * l.kh * l.kw * l.co);
    Ok(())
}

// ---------------------------------------------------------------------------
// Table IV
// ---------------------------------------------------------------------------

fn design_point(name: &str, net: arch::NetworkSpec, frames: usize,
                rate: f64) -> anyhow::Result<PerfRow> {
    // Paper accounting: the session report's MOPs is the *theoretical*
    // synaptic op count per frame (Table IV "kFPS x MOPs"); the
    // measured spike-gated op count drives the energy model.
    let mut session = Session::builder().network(net).build()?;
    let shape = session.input_shape();
    let rep = session.infer_batch(&synth_frames(shape, frames, rate, 7));
    Ok(rep.perf_row(name))
}

fn table4(args: &Args) -> anyhow::Result<()> {
    let frames = args.get_usize("frames", 2);
    let rate = args.get_f64("rate", 0.15);
    println!("Table IV — accuracy/throughput/power/efficiency\n");
    println!("{}", PerfRow::header());

    let points: Vec<(&str, arch::NetworkSpec)> = vec![
        ("Ours-1 SCNN3", arch::scnn3()),
        ("Ours-2 SCNN3 (4,2)",
         arch::scnn3().try_with_parallel_factors(&[4, 2])?),
        ("Ours-3 SCNN5", arch::scnn5()),
        ("Ours-4 SCNN5 (4,4,2,1)",
         arch::scnn5().try_with_parallel_factors(&[4, 4, 2, 1])?),
        ("Ours-5 vMobileNet", arch::vmobilenet()),
    ];
    let mut ours = Vec::new();
    for (name, net) in points {
        let row = design_point(name, net, frames, rate)?;
        println!("{row}");
        ours.push(row);
    }

    println!("\npaper's reported rows (for shape comparison):");
    println!("{:<22} {:>9} {:>9} {:>8} {:>10} {:>12}",
             "design", "FPS", "GOPS", "W", "GOPS/W", "GOPS/W/PE");
    for (name, fps, gops, w, gpw, gpwpe) in
        sti_snn::metrics::paper_ours_rows()
    {
        println!("{name:<22} {fps:>9.1} {gops:>9.2} {w:>8.2} {gpw:>10.2} \
                  {gpwpe:>12.3}");
    }

    println!("\nSOTA comparison rows (paper Table IV, cited):");
    println!("{}", PerfRow::header());
    for r in sti_snn::metrics::sota_rows() {
        println!("{r}");
    }

    // Headline checks.
    let s_base = &ours[2];
    let s_par = &ours[3];
    println!("\nheadline: SCNN5 speedup {:.2}x (paper 4.0x), \
              efficiency gain {:.2}x (paper 3.49x), \
              Ours-4 GOPS/W/PE {:.3} (paper 0.14)",
             s_par.fps / s_base.fps,
             s_par.gops_per_w / s_base.gops_per_w,
             s_par.gops_per_w_per_pe);
    Ok(())
}

// ---------------------------------------------------------------------------
// Table V
// ---------------------------------------------------------------------------

fn table5(_args: &Args) -> anyhow::Result<()> {
    let m = ResourceModel::default();
    println!("Table V — resource utilisation on ZCU102 (xczu9eg)\n");
    println!("{:<24} {:>6} {:>10} {:>8} {:>10} {:>8} {:>8}",
             "design", "PEs", "LUT", "LUT %", "FF", "BRAM36", "BRAM %");
    for (name, net) in [
        ("SCNN3 (4,2)",
         arch::scnn3().try_with_parallel_factors(&[4, 2])?),
        ("SCNN5 (4,4,2,1)",
         arch::scnn5().try_with_parallel_factors(&[4, 4, 2, 1])?),
        ("vMobileNet", arch::vmobilenet()),
    ] {
        let r = m.network(&net, 1);
        println!("{:<24} {:>6} {:>10} {:>8.2} {:>10} {:>8.1} {:>8.2}",
                 name, net.total_pes(), r.lut, r.lut_util(), r.ff,
                 r.bram36, r.bram_util());
    }
    println!("\npaper: LUT 3.5K/25.52K/7.7K; BRAM 11.5/527.5/13.x; \
              PE 54/99/40; 200 MHz; Int8; IF neurons; OS dataflow");
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 11
// ---------------------------------------------------------------------------

fn fig11(args: &Args) -> anyhow::Result<()> {
    let frames = args.get_usize("frames", 1);
    let rate = args.get_f64("rate", 0.15);
    println!("Fig. 11 — SCNN5 per-conv-layer Vmem memory + energy, T1 vs \
              T2\n");
    let mut results = Vec::new();
    for t in [1usize, 2] {
        let mut session = Session::builder()
            .network(arch::scnn5())
            .timesteps(t)
            .build()?;
        let shape = session.input_shape();
        let rep = session
            .infer_batch(&synth_frames(shape, frames, rate, 11));
        results.push(rep);
    }
    println!("{:<14} {:>14} {:>14} {:>16} {:>16}",
             "layer", "T1 Vmem KB", "T2 Vmem KB", "T1 energy uJ/frm",
             "T2 energy uJ/frm");
    let r1 = &results[0];
    let r2 = &results[1];
    let mut t1_kb = 0.0;
    let mut t2_kb = 0.0;
    let (mut e1_tot, mut e2_tot) = (0.0, 0.0);
    let mut conv_idx = 0;
    for li in 0..r1.layer_cycles.len() {
        if !r1.layer_names[li].starts_with("conv") {
            continue;
        }
        conv_idx += 1;
        let kb1 = r1.layer_vmem_bytes[li] as f64 / 1024.0;
        let kb2 = r2.layer_vmem_bytes[li] as f64 / 1024.0;
        let e1 = r1.layer_energy[li].total_j() * 1e6;
        let e2 = r2.layer_energy[li].total_j() * 1e6;
        t1_kb += kb1;
        t2_kb += kb2;
        e1_tot += e1;
        e2_tot += e2;
        println!("{:<14} {:>14.1} {:>14.1} {:>16.2} {:>16.2}",
                 format!("conv{conv_idx}"), kb1, kb2, e1, e2);
    }
    println!("{:<14} {:>14.1} {:>14.1} {:>16.2} {:>16.2}",
             "total", t1_kb, t2_kb, e1_tot, e2_tot);
    println!("\nheadline: Vmem saved at T1 = {:.1} KB (paper: 126 KB); \
              energy T2/T1 = {:.2}x (paper: ~2x, 1.3 J vs 0.6 J)",
             t2_kb - t1_kb, e2_tot / e1_tot);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 12
// ---------------------------------------------------------------------------

fn fig12(args: &Args) -> anyhow::Result<()> {
    let frames = args.get_usize("frames", 1);
    let rate = args.get_f64("rate", 0.15);
    println!("Fig. 12 — SCNN5 delay/power/LUT/FF before vs after output-\
              channel parallelism\n");
    let energy = EnergyModel::default();
    let rm = ResourceModel::default();

    let mut rows = Vec::new();
    for (name, net, pipelined) in [
        ("unpipelined", arch::scnn5(), false),
        ("pipelined", arch::scnn5(), true),
        ("pipelined+parallel(4,4,2,1)",
         arch::scnn5().try_with_parallel_factors(&[4, 4, 2, 1])?, true),
    ] {
        let mut session = Session::builder()
            .network(net.clone())
            .pipelined(pipelined)
            .build()?;
        let shape = session.input_shape();
        let rep = session
            .infer_batch(&synth_frames(shape, frames, rate, 13));
        let per_frame_ms = if pipelined {
            cycles_to_ms(rep.t_max)
        } else {
            cycles_to_ms(rep.t_sum)
        };
        let fps = 1000.0 / per_frame_ms;
        let power = energy.avg_power(rep.energy_per_frame_j, fps,
                                     rep.pes, rep.resources.bram36);
        let res = rm.network(&net, 1);
        println!("{name:<32} delay {per_frame_ms:>7.2} ms  power \
                  {power:>5.2} W  LUT {:>6}  FF {:>6}", res.lut, res.ff);
        rows.push(per_frame_ms);

        // Per-layer LUT/FF before/after (the bar chart's lower panel).
        if pipelined {
            for (i, r) in rm.per_conv_layer(&net, 1).iter().enumerate() {
                println!("    conv{} LUT {:>6} FF {:>6}",
                         i + 1, r.lut, r.ff);
            }
        }
    }
    println!("\nheadline: {:.2} -> {:.2} -> {:.2} ms (paper: 24.95 -> \
              10.06 -> 2.52 ms, 9.9x); ours {:.1}x",
             rows[0], rows[1], rows[2], rows[0] / rows[2]);
    Ok(())
}

// ---------------------------------------------------------------------------
// optimize / explore / run / serve
// ---------------------------------------------------------------------------

fn optimize(args: &Args) -> anyhow::Result<()> {
    let net = net_for(args)?;
    let budget = args.get_usize("pe-budget", 99);
    let choice = scheduler::optimize_factors(
        &net, budget, &ConvLatencyParams::optimized());
    println!("model {} | PE budget {budget}", net.name);
    println!("chosen factors: {:?} ({} PEs)", choice.factors, choice.pes);
    println!("pipeline interval: {} cycles = {:.2} ms (was {:.2} ms; \
              speedup {:.2}x)",
             choice.t_max, cycles_to_ms(choice.t_max),
             cycles_to_ms(choice.t_max_base), choice.speedup());
    Ok(())
}

/// Build a cost model for `net`, calibrated against the simulator
/// unless the user opted out.
fn cost_model_for(args: &Args, net: &arch::NetworkSpec, timesteps: usize)
                  -> dse::CostModel {
    let mut model = dse::CostModel::default();
    if !args.has("no-calibrate") {
        println!("calibrating cost model against the simulator ...");
        let rate = args.get_f64("rate",
                                dse::AutoTuneOptions::default().rate);
        model.calibration = dse::calibrate(net, &model.timing,
                                           &dse::CalibrationConfig {
            rate,
            timesteps,
            intra_parallel: args.get_usize("intra-parallel", 1),
            pipelined: !args.has("no-pipelined"),
            ..Default::default()
        });
    }
    model
}

fn explore(args: &Args) -> anyhow::Result<()> {
    let name = args.get_str("model", "scnn3");
    let net = arch::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
    let budget = args.get_usize("pe-budget", 8 * dse::min_pes(&net));
    let max_replicas = args.get_usize("max-replicas", 4);
    let t = args.get_usize("timesteps", 1);
    let model = cost_model_for(args, &net, t);
    let space = dse::SearchSpace::new(net, budget)
        .with_replicas(max_replicas)
        .with_timesteps(t);
    let ex = dse::explore(&space, &model);

    println!("model {} | PE budget {budget} | max replicas \
              {max_replicas} | T = {t}",
             space.net.name);
    println!("{} candidates, {} evaluated, frontier size {}\n",
             ex.candidates, ex.evaluated, ex.frontier.len());
    print!("{}", dse::frontier_table(&ex));
    match &ex.chosen {
        Some(c) => println!("\nchosen: factors {:?} x{} replica(s), \
                             backend {}, {:.1} FPS, {:.2} W, fits = {}",
                            c.candidate.factors, c.candidate.replicas,
                            c.candidate.backend, c.pool_fps, c.power_w,
                            c.fits),
        None => println!("\nno candidate fits the ZCU102 budget"),
    }
    let path = args.get_str("report", "dse_report.json").to_string();
    dse::write_report(&path, &ex, &space)?;
    println!("report written to {path}");
    Ok(())
}

/// Wire timestamps are u32 µs: reject --windows x --window-us combos
/// that would wrap (and so emit an unsorted, unreplayable stream).
fn check_timestamp_space(windows: usize, window_us: u32)
                         -> anyhow::Result<()> {
    anyhow::ensure!(
        windows as u64 * window_us as u64 <= u32::MAX as u64,
        "--windows {windows} x --window-us {window_us} exceeds the u32 \
         microsecond timestamp space ({} µs)", u32::MAX);
    Ok(())
}

/// Window policy from `--window` (default one window per 1000 µs).
fn window_for(args: &Args) -> anyhow::Result<WindowPolicy> {
    let s = args.get_str("window", "us:1000");
    WindowPolicy::parse(s).ok_or_else(|| {
        anyhow::anyhow!("bad --window {s:?} (count:N or us:N)")
    })
}

/// `run --events PATH|synth`: stream sorted address events through the
/// windowed ingestion path and classify window by window.
fn run_events(args: &Args, session: &mut Session, src: &str)
              -> anyhow::Result<()> {
    let (h, w, c) = session.input_shape();
    let window = window_for(args)?;
    let events: Vec<DvsEvent> = if src == "synth" {
        let windows = args.get_usize("windows", 4);
        let rate = args.get_f64("rate", 0.15);
        let us = match window {
            WindowPolicy::TimeUs(us) => us,
            WindowPolicy::Count(_) => 1000,
        };
        check_timestamp_space(windows, us)?;
        stream::synth_events(h, w, c, windows, rate, us, 17)
    } else {
        let bytes = std::fs::read(src)
            .with_context(|| format!("read event file {src}"))?;
        stream::decode_events(&bytes)?
    };
    println!("streaming {} events into ({h}, {w}, {c}) windows \
              ({window}, backend={})",
             events.len(), session.backend());
    let t0 = Instant::now();
    let out = session.infer_events(&events, window)?;
    let host_s = t0.elapsed().as_secs_f64().max(1e-9);
    println!("{} windows from {} events; host {:.1} k events/s, \
              {:.1} windows/s",
             out.stats.windows, out.stats.events,
             out.stats.events as f64 / host_s / 1e3,
             out.stats.windows as f64 / host_s);
    for (i, inf) in out.windows.iter().enumerate() {
        println!("  window {i:>4}: class {}", inf.class);
    }
    Ok(())
}

fn run(args: &Args) -> anyhow::Result<()> {
    let net = net_for(args)?;
    let frames = args.get_usize("frames", 4);
    let rate = args.get_f64("rate", 0.15);
    let t = args.get_usize("timesteps", 1);
    let intra = args.get_usize("intra-parallel", 1);
    let backend = backend_for(args)?.unwrap_or_default();
    let trace_path = args.get("trace").map(|p| p.to_string());
    let sink = trace_path
        .as_ref()
        .map(|_| Arc::new(TraceSink::new(DEFAULT_TRACE_CAPACITY)));
    let mut builder = Session::builder()
        .network(net)
        .backend(backend)
        .timesteps(t)
        .intra_parallel(intra)
        .pipelined(!args.has("no-pipelined"));
    if let Some(s) = &sink {
        builder = builder.trace(s.clone());
    }
    let mut session = builder.build()?;
    if args.has("events") {
        // `--events` immediately followed by another --flag parses as
        // a bare switch; never silently fall through to the dense path
        // the user explicitly asked to leave.
        anyhow::bail!("run --events needs a value: a .aer file path, \
                       or `synth`");
    }
    if let Some(src) = args.get("events") {
        let src = src.to_string();
        run_events(args, &mut session, &src)?;
    } else {
        let shape = session.input_shape();
        println!("running {frames} frames of {shape:?} at rate {rate}, \
                  T={t}, backend={backend}, intra-parallel={intra}");
        let rep =
            session.infer_batch(&synth_frames(shape, frames, rate, 17));
        println!("t_max {} cycles ({:.3} ms); t_sum {} cycles; \
                  steady-state {:.1} FPS",
                 rep.t_max, cycles_to_ms(rep.t_max), rep.t_sum,
                 rep.fps_steady);
        println!("ops/frame {:.2} M; dyn energy {:.1} uJ/frame",
                 rep.ops_per_frame as f64 / 1e6,
                 rep.energy_per_frame_j * 1e6);
        println!("predictions: {:?}", rep.predictions);
        for (n, c) in rep.layer_names.iter().zip(&rep.layer_cycles) {
            println!("  {n:<20} {c:>12} cycles");
        }
        // Streamed-schedule row-channel accounting (host-side):
        // link i connects layer i to layer i+1.
        for (i, s) in rep.channel_stats.iter().enumerate() {
            println!("  link {i}: {} rows sent, {} backpressure \
                      wait(s), max occupancy {}",
                     s.sends, s.backpressure_waits, s.max_occupancy);
        }
    }
    if let (Some(path), Some(sink)) = (&trace_path, &sink) {
        std::fs::write(path, sink.to_chrome_json())
            .with_context(|| format!("write trace {path}"))?;
        println!("trace: {} span(s) recorded ({} dropped) -> {path} \
                  (load in ui.perfetto.dev or chrome://tracing)",
                 sink.len(), sink.dropped());
    }
    Ok(())
}

/// `gen-events`: write a synthetic DVS-like event file (concatenated
/// 12-byte LE records, sorted by timestamp — `codec::stream` docs)
/// sized for a model's post-encoder input, for load-testing
/// `run --events` and the server's events mode.
fn gen_events(args: &Args) -> anyhow::Result<()> {
    let name = args.get_str("model", "scnn3");
    let net = arch::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
    let (h, w, c) = net.accel_input_shape();
    let windows = args.get_usize("windows", 16);
    let rate = args.get_f64("rate", 0.15);
    let window_us_raw = args.get_u64("window-us", 1000);
    anyhow::ensure!(window_us_raw > 0 && window_us_raw <= u32::MAX as u64,
                    "--window-us must be in 1..={}", u32::MAX);
    let window_us = window_us_raw as u32;
    check_timestamp_space(windows, window_us)?;
    let seed = args.get_u64("seed", 17);
    let out = args.get_str("out", "events.aer");
    let events = stream::synth_events(h, w, c, windows, rate, window_us,
                                      seed);
    std::fs::write(out, stream::encode_events(&events))
        .with_context(|| format!("write {out}"))?;
    println!("{}: {} events over {windows} windows of {window_us} µs \
              for {} ({h}x{w}x{c}), {} bytes",
             out, events.len(), net.name,
             events.len() * DvsEvent::WIRE_BYTES);
    println!("replay: sti-snn run --model {name} --events {out} \
              --window us:{window_us}");
    Ok(())
}

/// Serving backend for the artifact path: PJRT encoder -> session
/// pipeline -> class; logits from the reference PJRT full-model graph.
struct SimBackend {
    rt: Runtime,
    session: Session,
    enc_shape: (usize, usize, usize),
    input_len: usize,
}

impl Backend for SimBackend {
    fn infer(&mut self, image: &[f32]) -> anyhow::Result<(usize, Vec<f32>)> {
        let frame = self.rt.encode("encoder", image, self.enc_shape)?;
        let class = self.session.infer(frame)?.class;
        // Logits from the reference PJRT full-model graph.
        let logits = self.rt.logits("model", image)?;
        Ok((class, logits))
    }

    fn input_len(&self) -> usize {
        self.input_len
    }
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let name = args.get_str("model", "scnn3");
    let addr = args.get_str("addr", "127.0.0.1:7878").to_string();
    let backend = backend_for(args)?;
    let max_batch = args.get_usize("max-batch", 16);
    let max_wait = Duration::from_millis(args.get_u64("max-wait-ms", 5));
    let t = args.get_usize("timesteps", 1);

    // Accept `--events` with or without an (ignored) value — the CLI
    // parser turns `--events X` into a valued flag, and silently
    // dropping the intent would disable the bounded queue + the
    // artifact-backend guard below.
    let events = args.has("events") || args.get("events").is_some();
    // --events implies a bounded queue so overload sheds explicitly;
    // --queue-cap overrides (0 = unbounded).
    let queue_cap =
        args.get_usize("queue-cap", if events { 64 } else { 0 });
    if events && !(args.has("synthetic") || args.has("auto-tune")) {
        // Never silently swap trained artifacts for random weights:
        // the artifact/PJRT backend is dense-only, so events serving
        // must be asked for together with the simulator path.
        anyhow::bail!("serve --events requires --synthetic (or \
                       --auto-tune): the artifact/PJRT backend is \
                       dense-only");
    }
    let online = args.has("online-tune");
    if online && !(args.has("synthetic") || args.has("auto-tune")) {
        // The controller rebuilds simulator pipelines for every new
        // generation; the single-threaded PJRT path cannot be swapped.
        anyhow::bail!("serve --online-tune requires --synthetic (or \
                       --auto-tune): generation swaps rebuild \
                       simulator pipelines");
    }
    if (args.get("chaos").is_some() || args.get("watchdog-ms").is_some())
        && !(args.has("synthetic") || args.has("auto-tune"))
    {
        // Fault injection targets the replica pool and the watchdog
        // monitors the streamed simulator schedule; neither exists on
        // the single-threaded PJRT path.
        anyhow::bail!("serve --chaos / --watchdog-ms require \
                       --synthetic (or --auto-tune): supervision \
                       targets the simulator pool");
    }

    if args.has("synthetic") || args.has("auto-tune") {
        // Simulator-only serving: no artifacts, no XLA; one pipeline
        // replica per worker thread drains the shared queue. The
        // session facade resolves the whole configuration (an explicit
        // --replicas pins the auto-tune search to that split; an
        // explicit --backend swaps the host compute path only).
        let mut builder = Session::builder()
            .model(name)
            .timesteps(t)
            .intra_parallel(args.get_usize("intra-parallel", 1))
            .pipelined(!args.has("no-pipelined"))
            .queue(max_batch, max_wait)
            .queue_capacity(queue_cap);
        if let Some(b) = backend {
            builder = builder.backend(b);
        }
        if let Some(ms) = args.get("watchdog-ms") {
            let ms: u64 = ms.parse().map_err(|_| {
                anyhow::anyhow!("invalid --watchdog-ms {ms:?}")
            })?;
            println!("watchdog: {} ms streamed-frame deadline \
                      (serial retry on fire)", ms);
            builder = builder
                .watchdog(WatchdogPolicy::with_deadline_ms(ms));
        }
        if let Some(path) = args.get("chaos") {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading chaos plan {path}"))?;
            let plan = FaultPlan::from_json(&text)
                .with_context(|| format!("parsing chaos plan {path}"))?;
            println!("chaos: injecting {} fault(s) from {path} \
                      (seed {})", plan.events.len(), plan.seed);
            builder = builder.chaos(plan);
        }
        if let Some(r) = args.get("replicas") {
            let r: usize = r.parse().map_err(|_| {
                anyhow::anyhow!("invalid --replicas {r:?}")
            })?;
            builder = builder.replicas(r.max(1));
        }
        if args.has("auto-tune") {
            println!("auto-tune: calibrating + exploring ...");
            let defaults = dse::AutoTuneOptions::default();
            let net = arch::by_name(name)
                .ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?;
            builder = builder.auto_tune(dse::AutoTuneOptions {
                pe_budget: Some(args.get_usize(
                    "pe-budget", 8 * dse::min_pes(&net))),
                max_replicas: args.get_usize("max-replicas",
                                             defaults.max_replicas),
                timesteps: t,
                rate: args.get_f64("rate", defaults.rate),
                intra_parallel: args.get_usize("intra-parallel", 1),
                pipelined: !args.has("no-pipelined"),
            });
        }
        if online {
            let d = RetunePolicy::default();
            let policy = RetunePolicy {
                interval: Duration::from_millis(
                    args.get_u64("retune-interval", 2000)),
                cooldown: Duration::from_millis(args.get_u64(
                    "retune-cooldown", d.cooldown.as_millis() as u64)),
                min_frames: args.get_u64("retune-min-frames",
                                         d.min_frames),
                ..d
            };
            println!("online-tune: interval {} ms, cooldown {} ms, \
                      min frames {}",
                     policy.interval.as_millis(),
                     policy.cooldown.as_millis(), policy.min_frames);
            builder = builder.online_tune(policy);
            if let Some(path) = args.get("retune-log") {
                builder = builder.retune_log(path);
            }
        }
        let session = builder.build()?;
        if let Some(best) = session.tuned() {
            println!("auto-tune: factors {:?}, {} replica(s), backend \
                      {} ({:.1} simulated FPS, {:.2} W, {} LUT)",
                     best.candidate.factors, best.candidate.replicas,
                     best.candidate.backend, best.pool_fps,
                     best.power_w, best.resources.lut);
        }
        let (h, w, c) = session.input_shape();
        println!("serving synthetic {} on {addr} ({} replica(s), \
                  backend={}, newline-JSON + binary events protocols)",
                 session.net().name, session.replicas(),
                 session.backend());
        println!("events mode: ({h}, {w}, {c}) frames, queue capacity \
                  {} ({}); clients opt in with \
                  {{\"cmd\": \"events\", \"window\": \"us:1000\"}}",
                 queue_cap,
                 if queue_cap == 0 { "unbounded" } else { "sheds when \
                  full" });
        return session.serve(&addr, |a| println!("bound {a}"));
    }

    // Artifact serving: PJRT encoder + reference logits. The runtime is
    // single-threaded (the PJRT client is not Send), so this path runs
    // one pipeline regardless of --replicas.
    if args.get("replicas").is_some() {
        eprintln!("note: --replicas ignored for artifact serving (PJRT \
                   backend is single-threaded); use --synthetic for the \
                   replica pool");
    }
    let dir = artifacts_dir().join(name);
    let art = Artifact::load(&dir)?;
    let mut rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform());
    rt.load_hlo("encoder", &art.encoder_hlo(), art.net.input)?;
    rt.load_hlo("model", &art.model_hlo(), art.net.input)?;
    let session = Session::builder()
        .weights(Weights::Artifact(dir))
        .backend(backend.unwrap_or_default())
        .timesteps(t)
        .intra_parallel(args.get_usize("intra-parallel", 1))
        .build()?;
    let (h, w, c) = art.net.input;
    let backend = SimBackend {
        rt,
        session,
        enc_shape: art.encoder_out_shape(),
        input_len: h * w * c,
    };
    let server = Server::new(backend).with_queue(max_batch, max_wait);
    println!("serving {name} on {addr} (newline-JSON protocol)");
    server.serve(&addr, |a| println!("bound {a}"))
}
