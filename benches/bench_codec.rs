//! Bench: spike codec — vector ops, event encode/decode, compression
//! ratio vs firing rate (the SectionIV-E.1 interconnect argument).
//!
//! `cargo bench --bench bench_codec`

use sti_snn::codec::{EventCodec, SpikeFrame};
use sti_snn::util::bench::BenchSet;
use sti_snn::util::rng::Rng;

fn main() {
    let mut set = BenchSet::new("spike codec (SectionIV-C / SectionIV-E.1)");
    let mut rng = Rng::new(4);

    let frame = SpikeFrame::random(32, 32, 64, 0.1, &mut rng);
    let codec = EventCodec::new(32, 32, 64);

    set.run("encode 32x32x64 @ 10%", || {
        std::hint::black_box(codec.encode(&frame));
    });

    let (events, _) = codec.encode(&frame);
    set.run("decode 32x32x64 @ 10%", || {
        std::hint::black_box(codec.decode(&events));
    });

    set.run("frame vector extraction (28x28x16)", || {
        let f = SpikeFrame::zeros(28, 28, 16);
        for y in 0..28 {
            for x in 0..28 {
                std::hint::black_box(f.vector(y, x));
            }
        }
    });

    println!("\n--- compression ratio vs firing rate (32x32x64) ---");
    for rate in [0.001, 0.01, 0.05, 0.1, 0.2, 0.5] {
        let f = SpikeFrame::random(32, 32, 64, rate, &mut rng);
        let (_, stats) = codec.encode(&f);
        println!("rate {rate:>5}: events {:>5}/{:>5}, ratio {:.2}x",
                 stats.events, stats.pixels, stats.ratio());
    }
}
