//! Bench: Table IV regeneration — all five "Ours" design points with
//! FPS / GOPS / W / GOPS/W / GOPS/W/PE, printed paper-style, built
//! through the `Session` facade.
//!
//! `cargo bench --bench bench_table4`

use sti_snn::arch;
use sti_snn::codec::SpikeFrame;
use sti_snn::metrics::PerfRow;
use sti_snn::session::Session;
use sti_snn::util::bench::BenchSet;
use sti_snn::util::rng::Rng;

fn main() {
    let mut set = BenchSet::new("Table IV design points");

    let points: Vec<(&str, arch::NetworkSpec)> = vec![
        ("Ours-1 SCNN3", arch::scnn3()),
        ("Ours-2 SCNN3 (4,2)",
         arch::scnn3().try_with_parallel_factors(&[4, 2]).unwrap()),
        ("Ours-3 SCNN5", arch::scnn5()),
        ("Ours-4 SCNN5 (4,4,2,1)",
         arch::scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap()),
        ("Ours-5 vMobileNet", arch::vmobilenet()),
    ];

    let mut rows = Vec::new();
    for (name, net) in points {
        let mut session =
            Session::builder().network(net).build().unwrap();
        let shape = session.input_shape();
        let mut rng = Rng::new(7);
        let f = vec![SpikeFrame::random(shape.0, shape.1, shape.2, 0.15,
                                        &mut rng)];
        let mut row = None;
        set.run(name, || {
            let rep = session.infer_batch(&f);
            row = Some(rep.perf_row(name));
        });
        rows.push(row.unwrap());
    }

    println!("\n--- Table IV (ours, regenerated) ---");
    println!("{}", PerfRow::header());
    for r in &rows {
        println!("{r}");
    }
    println!("\n--- paper ---");
    for (name, fps, gops, w, gpw, gpwpe) in
        sti_snn::metrics::paper_ours_rows()
    {
        println!("{name:<22} FPS {fps:>7.1} GOPS {gops:>6.2} W {w:>5.2} \
                  GOPS/W {gpw:>6.2} /PE {gpwpe:>5.3}");
    }
}
