//! Ablation bench: the design choices DESIGN.md calls out, measured.
//!
//! * OS vs WS dataflow — traffic + cycles on the same layer
//!   (SectionII-C), both engines driven through the public
//!   `LayerEngine` trait: the WS baseline exercises the exact code
//!   path the pipeline runs engines through.
//! * Line buffer + spike vectors — off-chip input reads vs plain OS
//!   (Table III's reduction).
//! * Spike-event encoding vs dense inter-layer transfer (SectionIV-E.1)
//!   across firing rates.
//! * Adder tree vs serial psum combine (the Tpe reduction of SectionIV-E.2).
//!
//! `cargo bench --bench bench_ablation`

use sti_snn::arch::{scnn5, ConvLayer};
use sti_snn::codec::{EventCodec, SpikeFrame};
use sti_snn::dataflow::{self, ConvLatencyParams};
use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
use sti_snn::sim::engine::{LayerEngine, LayerStep};
use sti_snn::sim::memory::{DataKind, MemLevel};
use sti_snn::sim::ws_engine::WsEngine;
use sti_snn::sim::cycles_to_ms;
use sti_snn::util::bench::BenchSet;
use sti_snn::util::rng::Rng;

fn main() {
    let mut set = BenchSet::new("ablations (design choices)");

    // --- OS vs WS on the SCNN5 bottleneck layer ------------------------
    // Both engines run through the LayerEngine trait — the same
    // dispatch surface the streaming pipeline uses.
    let l: ConvLayer = scnn5().accel_convs()[0].clone();
    let mut rng = Rng::new(3);
    let input = SpikeFrame::random(l.in_h, l.in_w, l.ci, 0.15, &mut rng);
    let w = ConvWeights::random(&l, 1);

    let mut os: Box<dyn LayerEngine> = Box::new(ConvEngine::new(
        l.clone(), w.clone(), ConvLatencyParams::optimized(), 1));
    let mut os_rep: Option<LayerStep> = None;
    set.run("OS engine, scnn5 conv2 frame", || {
        os_rep = Some(os.process_frame(&input, true).1);
    });
    let mut ws: Box<dyn LayerEngine> =
        Box::new(WsEngine::new(l.clone(), w, 1));
    let mut ws_rep: Option<LayerStep> = None;
    set.run("WS engine, scnn5 conv2 frame", || {
        ws_rep = Some(ws.process_frame(&input, true).1);
    });
    let (os_rep, ws_rep) = (os_rep.unwrap(), ws_rep.unwrap());
    println!("\n--- OS vs WS (scnn5 conv2, T=1) ---");
    println!("psum+vmem traffic: OS {} vs WS {}",
             os_rep.counters.total_of_kind(DataKind::PartialSum)
                 + os_rep.counters.total_of_kind(DataKind::Vmem),
             ws_rep.counters.total_of_kind(DataKind::PartialSum));
    println!("modelled cycles:   OS {} ({:.2} ms) vs WS {} ({:.2} ms)",
             os_rep.cycles, cycles_to_ms(os_rep.cycles),
             ws_rep.cycles, cycles_to_ms(ws_rep.cycles));

    // --- Line buffer: measured off-chip reads vs the plain-OS model ----
    println!("\n--- line buffer + spike vectors (Table III ablation) ---");
    let dram_reads =
        os_rep.counters.reads_of(MemLevel::Dram, DataKind::InputSpike);
    let plain = dataflow::os_access(&l, 1).input_spikes;
    println!("off-chip input reads: with line buffer {dram_reads}, \
              plain OS {plain} ({:.0}x reduction)",
             plain as f64 / dram_reads as f64);

    // --- Event encoding vs dense transfer (rate sweep) -----------------
    println!("\n--- spike-event encoding vs dense (32x32x64 link) ---");
    let codec = EventCodec::new(32, 32, 64);
    for rate in [0.01, 0.05, 0.1, 0.3] {
        let f = SpikeFrame::random(32, 32, 64, rate, &mut rng);
        let (_, stats) = codec.encode(&f);
        println!("rate {rate:>4}: encoded {:>8} bits vs dense {:>8} \
                  bits ({:.2}x)",
                 stats.encoded_bits, stats.dense_bits, stats.ratio());
    }

    // --- Adder tree vs serial combine (Eq. 12 Tpes term) ---------------
    println!("\n--- psum combine: adder tree vs serial (scnn5 conv2) ---");
    for (name, t_pes) in [("adder tree (ceil log2 9 = 4)", None),
                          ("serial (9 cycles)", Some(9u64))] {
        let timing = ConvLatencyParams { t_rw: 0, t_pe: 1, t_pes };
        let lat = dataflow::conv_latency(&l, &timing);
        println!("{name:<28} layer latency {:.2} ms", cycles_to_ms(lat));
    }
}
