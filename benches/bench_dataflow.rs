//! Bench: analytical dataflow models + Table I / Table III regeneration.
//!
//! `cargo bench --bench bench_dataflow`
//! (hand-rolled harness — criterion is not vendored; see util::bench)

use sti_snn::arch;
use sti_snn::dataflow::{self, ConvLatencyParams};
use sti_snn::util::bench::BenchSet;

fn main() {
    let mut set = BenchSet::new("dataflow models (Tables I & III)");

    let scnn5 = arch::scnn5();
    let convs: Vec<_> = scnn5.accel_convs().into_iter().cloned().collect();

    set.run("table1: OS+WS access counts, scnn5 all layers", || {
        for c in &convs {
            std::hint::black_box(dataflow::os_access(c, 1));
            std::hint::black_box(dataflow::ws_access(c, 1));
        }
    });

    set.run("table3: conv-mode access counts, all models", || {
        for net in [arch::scnn3(), arch::scnn5(), arch::vmobilenet()] {
            for c in net.accel_convs() {
                std::hint::black_box(dataflow::conv_mode_access(c, 1));
            }
        }
    });

    set.run("eq12: pipeline latency model, scnn5", || {
        std::hint::black_box(dataflow::pipeline_latency(
            &scnn5, &ConvLatencyParams::optimized(), 1));
    });

    // Regenerate the table rows (recorded in bench output for
    // EXPERIMENTS.md).
    println!("\n--- Table I (scnn5 conv2, T=1 vs T=2) ---");
    let c = &convs[0];
    for t in [1, 2] {
        let os = dataflow::os_access(c, t);
        let ws = dataflow::ws_access(c, t);
        println!("T={t}: OS in/w/p = {}/{}/{} | WS = {}/{}/{}",
                 os.input_spikes, os.weights, os.partial_sums,
                 ws.input_spikes, ws.weights, ws.partial_sums);
    }
}
