//! Bench: cycle-level conv engine throughput (simulation speed itself —
//! the §Perf hot path) across modes, parallel factors, and functional
//! compute backends (event-driven `accurate`, bit-plane popcount
//! `word-parallel`, and occupancy-skipping `sparse`; see
//! `sim::backend`). A dedicated density sweep times sparse vs
//! word-parallel at three activity levels — the crossover point where
//! occupancy skipping stops paying.
//!
//! Every backend set also cross-checks bit-exactness and report
//! equality before timing, so the speedup numbers are guaranteed to be
//! apples-to-apples.
//!
//! `cargo bench --bench bench_sim_engine`

use sti_snn::arch::{ConvLayer, ConvMode};
use sti_snn::codec::SpikeFrame;
use sti_snn::coordinator::pipeline::{Pipeline, PipelineConfig};
use sti_snn::dataflow::ConvLatencyParams;
use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
use sti_snn::sim::BackendKind;
use sti_snn::util::bench::BenchSet;
use sti_snn::util::rng::Rng;

fn layer(mode: ConvMode, ci: usize, co: usize, hw: usize,
         parallel: usize) -> ConvLayer {
    let k = if mode == ConvMode::Pointwise { 1 } else { 3 };
    ConvLayer {
        mode, in_h: hw, in_w: hw, ci, co, kh: k, kw: k, pad: k / 2,
        encoder: false, parallel,
    }
}

/// Bench one layer under both backends; cross-check equivalence and
/// print the word-parallel speedup.
fn compare(set: &mut BenchSet, name: &str, l: ConvLayer, seed: u64,
           rate: f64, rng: &mut Rng) -> (f64, f64) {
    let w = ConvWeights::random(&l, seed);
    let input = SpikeFrame::random(l.in_h, l.in_w, l.ci, rate, rng);
    let timing = ConvLatencyParams::optimized();

    let mut acc = ConvEngine::new(l.clone(), w.clone(), timing, 1);
    let mut wp = ConvEngine::with_backend(l, w, timing, 1,
                                          BackendKind::WordParallel);

    // Equivalence gate before timing anything.
    let (oa, ra) = acc.run_frame(&input, true);
    let (ow, rw) = wp.run_frame(&input, true);
    assert_eq!(oa, ow, "{name}: backends diverge functionally");
    assert_eq!(ra, rw, "{name}: backends diverge on reports");

    let r_acc = set.run(&format!("{name} [accurate]"), || {
        std::hint::black_box(acc.run_frame(&input, true));
    });
    let acc_ns = r_acc.median_ns;
    let r_wp = set.run(&format!("{name} [word-parallel]"), || {
        std::hint::black_box(wp.run_frame(&input, true));
    });
    let wp_ns = r_wp.median_ns;
    println!("    -> word-parallel speedup {:.2}x", acc_ns / wp_ns);
    (acc_ns, wp_ns)
}

/// Intra-frame band scaling on one layer: bit-exactness gate against
/// the single-band run, then frames/s per band count.
fn band_scaling(set: &mut BenchSet, name: &str, l: ConvLayer, seed: u64,
                rate: f64, rng: &mut Rng) {
    let w = ConvWeights::random(&l, seed);
    let input = SpikeFrame::random(l.in_h, l.in_w, l.ci, rate, rng);
    let timing = ConvLatencyParams::optimized();
    let mut base = ConvEngine::with_backend(
        l.clone(), w.clone(), timing, 1, BackendKind::WordParallel);
    let (o1, r1) = base.run_frame(&input, true);
    let mut base_ns = 0.0;
    for bands in [1usize, 2, 4] {
        let mut eng = ConvEngine::with_backend(
            l.clone(), w.clone(), timing, 1, BackendKind::WordParallel)
            .with_intra_parallel(bands);
        let (ob, rb) = eng.run_frame(&input, true);
        assert_eq!(o1, ob, "{name}: bands={bands} diverges functionally");
        assert_eq!(r1, rb, "{name}: bands={bands} diverges on reports");
        let r = set.run(&format!("{name} [wp bands={bands}]"), || {
            std::hint::black_box(eng.run_frame(&input, true));
        });
        if bands == 1 {
            base_ns = r.median_ns;
        } else {
            println!("    -> {bands} bands: {:.2}x over single band",
                     base_ns / r.median_ns);
        }
    }
}

fn main() {
    let mut set = BenchSet::new("conv engine (cycle-level sim speed)");
    let mut rng = Rng::new(1);

    // SCNN3 conv2-sized standard layer — the acceptance workload:
    // standard conv at default sparsity.
    let (acc_ns, wp_ns) = compare(
        &mut set, "standard 28x28 16->32 (scnn3 conv2)",
        layer(ConvMode::Standard, 16, 32, 28, 1), 2, 0.2, &mut rng);
    let ops = 28 * 28 * 32 * 16 * 9u64;
    println!("    -> sim rate {:.1} (accurate) / {:.1} (word-parallel) \
              M synaptic ops/s wall",
             ops as f64 / (acc_ns / 1e9) / 1e6,
             ops as f64 / (wp_ns / 1e9) / 1e6);

    // SCNN5 conv2-sized layer (the heavyweight).
    let (acc_ns, wp_ns) = compare(
        &mut set, "standard 16x16 64->128 p4 (scnn5 conv2)",
        layer(ConvMode::Standard, 64, 128, 16, 4), 3, 0.15, &mut rng);
    let ops = 16 * 16 * 128 * 64 * 9u64;
    println!("    -> sim rate {:.1} (accurate) / {:.1} (word-parallel) \
              M synaptic ops/s wall",
             ops as f64 / (acc_ns / 1e9) / 1e6,
             ops as f64 / (wp_ns / 1e9) / 1e6);

    // Wide standard layer: 256 input channels = 4 words per tap.
    compare(&mut set, "standard 8x8 256->256 (scnn5 conv4)",
            layer(ConvMode::Standard, 256, 256, 8, 1), 7, 0.15, &mut rng);

    // Depthwise + pointwise (vMobileNet block).
    compare(&mut set, "depthwise 14x14 c32",
            layer(ConvMode::Depthwise, 32, 32, 14, 1), 4, 0.25, &mut rng);
    compare(&mut set, "pointwise 14x14 32->64",
            layer(ConvMode::Pointwise, 32, 64, 14, 1), 5, 0.25, &mut rng);

    // CIFAR-scale synthetic layer (32x32 frame, scnn5 conv1-sized
    // post-encoder geometry) — the acceptance workload for the
    // zero-allocation incremental hot path, plus intra-frame band
    // scaling on top of the word-parallel backend.
    compare(&mut set, "standard 32x32 64->64 (cifar-scale)",
            layer(ConvMode::Standard, 64, 64, 32, 1), 9, 0.15, &mut rng);
    band_scaling(&mut set, "standard 32x32 64->64 (cifar-scale)",
                 layer(ConvMode::Standard, 64, 64, 32, 1), 9, 0.15,
                 &mut rng);

    sparse_density_sweep(&mut set, &mut rng);

    pipeline_streaming(&mut rng);
}

/// Sparse vs word-parallel across input densities on the cifar-scale
/// layer: word-parallel is density-invariant, sparse tracks activity —
/// the printed ratios locate the density crossover where occupancy
/// skipping stops paying.
fn sparse_density_sweep(set: &mut BenchSet, rng: &mut Rng) {
    let timing = ConvLatencyParams::optimized();
    for density in [0.02, 0.15, 0.4] {
        let l = layer(ConvMode::Standard, 64, 64, 32, 1);
        let w = ConvWeights::random(&l, 11);
        let input =
            SpikeFrame::random(l.in_h, l.in_w, l.ci, density, rng);
        let mut wp = ConvEngine::with_backend(
            l.clone(), w.clone(), timing, 1, BackendKind::WordParallel);
        let mut sp = ConvEngine::with_backend(
            l, w, timing, 1, BackendKind::Sparse);
        let (ow, rw) = wp.run_frame(&input, true);
        let (os, rs) = sp.run_frame(&input, true);
        assert_eq!(ow, os, "d={density}: backends diverge functionally");
        assert_eq!(rw, rs, "d={density}: backends diverge on reports");
        let wp_ns = set.run(
            &format!("standard 32x32 64->64 [word-parallel d={density}]"),
            || {
                std::hint::black_box(wp.run_frame(&input, true));
            }).median_ns;
        let sp_ns = set.run(
            &format!("standard 32x32 64->64 [sparse d={density}]"),
            || {
                std::hint::black_box(sp.run_frame(&input, true));
            }).median_ns;
        println!("    -> d={density}: sparse {:.2}x vs word-parallel",
                 wp_ns / sp_ns);
    }
}

/// Whole-pipeline wall latency on scnn5: the streamed inter-layer
/// schedule (per-layer workers + bounded row channels) vs the serial
/// layer loop. Reports are bit-identical by construction (pinned in
/// tests/stream_exec.rs); the gate here re-checks predictions before
/// timing. The speedup needs spare host cores — on a single-core host
/// expect ~1x.
fn pipeline_streaming(rng: &mut Rng) {
    let mut set = BenchSet::new(
        "inter-layer row streaming (scnn5 pipeline, word-parallel)");
    let net = sti_snn::arch::scnn5();
    let config = |pipelined: bool| PipelineConfig {
        backend: BackendKind::WordParallel,
        pipelined,
        ..Default::default()
    };
    let mut streamed =
        Pipeline::random(net.clone(), config(true)).unwrap();
    let mut serial = Pipeline::random(net, config(false)).unwrap();
    let shape = streamed.input_shape();
    let frames: Vec<SpikeFrame> = (0..4)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.15, rng))
        .collect();
    let rp = streamed.run(&frames);
    let rs = serial.run(&frames);
    assert_eq!(rp.predictions, rs.predictions,
               "schedules diverge on predictions");
    assert_eq!(rp.layer_cycles, rs.layer_cycles,
               "schedules diverge on cycle reports");

    let r_streamed = set
        .run("scnn5 4-frame batch [streamed]", || {
            std::hint::black_box(streamed.run(&frames));
        })
        .clone();
    let r_serial = set
        .run("scnn5 4-frame batch [serial]", || {
            std::hint::black_box(serial.run(&frames));
        })
        .clone();
    println!("    -> streamed {:.2}x over serial ({} host cores)",
             r_serial.median_ns / r_streamed.median_ns,
             std::thread::available_parallelism()
                 .map(|c| c.get())
                 .unwrap_or(1));
}
