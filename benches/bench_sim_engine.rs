//! Bench: cycle-level conv engine throughput (simulation speed itself —
//! the §Perf hot path) across modes and parallel factors.
//!
//! `cargo bench --bench bench_sim_engine`

use sti_snn::arch::{ConvLayer, ConvMode};
use sti_snn::codec::SpikeFrame;
use sti_snn::dataflow::ConvLatencyParams;
use sti_snn::sim::conv_engine::{ConvEngine, ConvWeights};
use sti_snn::util::bench::BenchSet;
use sti_snn::util::rng::Rng;

fn layer(mode: ConvMode, ci: usize, co: usize, hw: usize,
         parallel: usize) -> ConvLayer {
    let k = if mode == ConvMode::Pointwise { 1 } else { 3 };
    ConvLayer {
        mode, in_h: hw, in_w: hw, ci, co, kh: k, kw: k, pad: k / 2,
        encoder: false, parallel,
    }
}

fn main() {
    let mut set = BenchSet::new("conv engine (cycle-level sim speed)");
    let mut rng = Rng::new(1);

    // SCNN3 conv2-sized standard layer.
    let l = layer(ConvMode::Standard, 16, 32, 28, 1);
    let w = ConvWeights::random(&l, 2);
    let input = SpikeFrame::random(28, 28, 16, 0.2, &mut rng);
    let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
    let r = set.run("standard 28x28 16->32 (scnn3 conv2)", || {
        std::hint::black_box(eng.run_frame(&input, true));
    });
    let ops = 28 * 28 * 32 * 16 * 9u64;
    println!("    -> sim rate {:.1} M synaptic ops/s wall",
             ops as f64 / (r.median_ns / 1e9) / 1e6);

    // SCNN5 conv2-sized layer (the heavyweight).
    let l = layer(ConvMode::Standard, 64, 128, 16, 4);
    let w = ConvWeights::random(&l, 3);
    let input = SpikeFrame::random(16, 16, 64, 0.15, &mut rng);
    let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
    let r = set.run("standard 16x16 64->128 p4 (scnn5 conv2)", || {
        std::hint::black_box(eng.run_frame(&input, true));
    });
    let ops = 16 * 16 * 128 * 64 * 9u64;
    println!("    -> sim rate {:.1} M synaptic ops/s wall",
             ops as f64 / (r.median_ns / 1e9) / 1e6);

    // Depthwise + pointwise (vMobileNet block).
    let l = layer(ConvMode::Depthwise, 32, 32, 14, 1);
    let w = ConvWeights::random(&l, 4);
    let input = SpikeFrame::random(14, 14, 32, 0.25, &mut rng);
    let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
    set.run("depthwise 14x14 c32", || {
        std::hint::black_box(eng.run_frame(&input, true));
    });

    let l = layer(ConvMode::Pointwise, 32, 64, 14, 1);
    let w = ConvWeights::random(&l, 5);
    let input = SpikeFrame::random(14, 14, 32, 0.25, &mut rng);
    let mut eng = ConvEngine::new(l, w, ConvLatencyParams::optimized(), 1);
    set.run("pointwise 14x14 32->64", || {
        std::hint::black_box(eng.run_frame(&input, true));
    });
}
