//! Bench: multi-pipeline parallel serving — request throughput of the
//! replica pool at N = 1 vs N = host-scaled replicas, the combined
//! word-parallel x replica speedup, and the DSE auto-tuned
//! configuration (what `serve --auto-tune` boots) against the serve
//! defaults. Every pool is constructed through the `Session` facade —
//! the exact stack the CLI serves.
//!
//! `cargo bench --bench bench_serve`

use std::time::{Duration, Instant};

use sti_snn::autotune::RetunePolicy;
use sti_snn::codec::SpikeFrame;
use sti_snn::dse::AutoTuneOptions;
use sti_snn::session::{Session, SessionBuilder};
use sti_snn::sim::BackendKind;
use sti_snn::util::bench::{fmt_ns, smoke_mode, BenchResult, BenchSet};
use sti_snn::util::rng::Rng;

fn frames(n: usize) -> Vec<SpikeFrame> {
    let mut rng = Rng::new(42);
    (0..n)
        .map(|_| SpikeFrame::random(28, 28, 16, 0.2, &mut rng))
        .collect()
}

/// Build the session, push every frame through its replica pool;
/// returns (requests/s, per-request mean ns), the predictions for
/// cross-checking, and the per-request end-to-end latencies (µs,
/// queue wait + compute) for percentile reporting.
fn pool_run(builder: SessionBuilder, fs: &[SpikeFrame])
            -> (f64, f64, Vec<usize>, Vec<u64>, Session) {
    let mut session = builder.build().expect("session builds");
    session.start_pool().expect("pool starts");
    let t0 = Instant::now();
    let rxs: Vec<_> = fs
        .iter()
        .map(|f| session.submit(f.clone()).unwrap())
        .collect();
    let mut preds = Vec::with_capacity(fs.len());
    let mut lat_us = Vec::with_capacity(fs.len());
    for rx in rxs {
        let r = rx.recv().unwrap();
        preds.push(r.prediction.unwrap());
        lat_us.push(r.latency_us);
    }
    let dt = t0.elapsed();
    let rps = fs.len() as f64 / dt.as_secs_f64();
    (rps, dt.as_nanos() as f64 / fs.len() as f64, preds, lat_us, session)
}

/// Print p50/p95/p99 of a per-request latency sample (µs).
fn print_percentiles(label: &str, lat_us: &mut [u64]) {
    lat_us.sort_unstable();
    let pct = |p: f64| {
        lat_us[((lat_us.len() - 1) as f64 * p).round() as usize]
    };
    println!("    -> {label} latency p50 {} / p95 {} / p99 {}",
             fmt_ns(pct(0.50) as f64 * 1e3),
             fmt_ns(pct(0.95) as f64 * 1e3),
             fmt_ns(pct(0.99) as f64 * 1e3));
}

fn builder(replicas: usize, backend: BackendKind) -> SessionBuilder {
    Session::builder()
        .model("scnn3")
        .backend(backend)
        .replicas(replicas)
        .queue(4, Duration::from_millis(2))
}

fn main() {
    let n_requests = if smoke_mode() { 4 } else { 32 };
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1);
    let big = cores.clamp(2, 8);

    let mut set = BenchSet::new(
        "replica-pool serving (scnn3, word-parallel backend)");
    let fs = frames(n_requests);

    let (rps1, ns1, preds1, mut lat1, s) =
        pool_run(builder(1, BackendKind::WordParallel), &fs);
    s.shutdown();
    set.add(BenchResult {
        name: "pool N=1".into(),
        iters: n_requests,
        mean_ns: ns1,
        median_ns: ns1,
        min_ns: ns1,
    });
    println!("pool N=1: {rps1:.1} req/s ({}/req)", fmt_ns(ns1));
    print_percentiles("pool N=1", &mut lat1);

    // The same pool on the serial layer schedule — the inter-layer
    // row-streaming comparison (reports are bit-identical; only the
    // execution schedule differs).
    let (rps_ser, ns_ser, preds_ser, mut lat_ser, s) = pool_run(
        builder(1, BackendKind::WordParallel).pipelined(false), &fs);
    s.shutdown();
    set.add(BenchResult {
        name: "pool N=1 [serial schedule]".into(),
        iters: n_requests,
        mean_ns: ns_ser,
        median_ns: ns_ser,
        min_ns: ns_ser,
    });
    assert_eq!(preds1, preds_ser, "serial schedule changed predictions");
    println!("pool N=1 serial schedule: {rps_ser:.1} req/s ({}/req)",
             fmt_ns(ns_ser));
    print_percentiles("pool N=1 serial", &mut lat_ser);
    println!("    -> inter-layer row streaming {:.2}x over the serial \
              schedule (layer workers need spare host cores; expect \
              ~1x on a single-core host)", rps1 / rps_ser);

    // Row-channel accounting of one streamed batch on the primary
    // pipeline: how hard each inter-layer link worked.
    let mut probe = builder(1, BackendKind::WordParallel)
        .build()
        .expect("session builds");
    let rep = probe.infer_batch(&fs);
    for (i, s) in rep.channel_stats.iter().enumerate() {
        println!("    link {i}: {} rows, {} backpressure wait(s), max \
                  occupancy {}",
                 s.sends, s.backpressure_waits, s.max_occupancy);
    }
    drop(probe);

    let (rps_n, ns_n, preds_n, mut lat_n, s) =
        pool_run(builder(big, BackendKind::WordParallel), &fs);
    s.shutdown();
    set.add(BenchResult {
        name: format!("pool N={big}"),
        iters: n_requests,
        mean_ns: ns_n,
        median_ns: ns_n,
        min_ns: ns_n,
    });
    println!("pool N={big}: {rps_n:.1} req/s ({}/req)", fmt_ns(ns_n));
    print_percentiles(&format!("pool N={big}"), &mut lat_n);
    assert_eq!(preds1, preds_n, "replica pool changed predictions");
    println!("    -> throughput scaling {:.2}x with {big} replicas on \
              {cores} host cores", rps_n / rps1);

    // Reference: the accurate backend at N=1, to show the combined
    // word-parallel + replica win end to end.
    let (rps_acc, ns_acc, preds_acc, _lat_acc, s) =
        pool_run(builder(1, BackendKind::Accurate), &fs);
    s.shutdown();
    set.add(BenchResult {
        name: "pool N=1 [accurate]".into(),
        iters: n_requests,
        mean_ns: ns_acc,
        median_ns: ns_acc,
        min_ns: ns_acc,
    });
    assert_eq!(preds1, preds_acc, "backends changed predictions");
    println!("pool N=1 accurate: {rps_acc:.1} req/s ({}/req)",
             fmt_ns(ns_acc));
    println!("    -> combined word-parallel x {big}-replica speedup \
              {:.2}x over accurate x 1", rps_n / rps_acc);

    // DSE auto-tuned configuration — the exact `serve --auto-tune`
    // recipe (Session::builder().auto_tune(..), same defaults) — vs
    // the serve defaults measured above (1 replica, accurate backend,
    // unit factors).
    let tuned_builder = Session::builder()
        .model("scnn3")
        .auto_tune(AutoTuneOptions {
            max_replicas: big,
            ..Default::default()
        })
        .queue(4, Duration::from_millis(2));
    let (rps_tuned, ns_tuned, preds_tuned, mut lat_tuned, s) =
        pool_run(tuned_builder, &fs);
    let best = s.tuned().expect("auto-tuned session").clone();
    s.shutdown();
    set.add(BenchResult {
        name: format!("pool auto-tuned ({:?} x{} {})",
                      best.candidate.factors, best.candidate.replicas,
                      best.candidate.backend),
        iters: n_requests,
        mean_ns: ns_tuned,
        median_ns: ns_tuned,
        min_ns: ns_tuned,
    });
    assert_eq!(preds1, preds_tuned, "auto-tuned pool changed predictions");
    println!("pool auto-tuned (factors {:?}, N={}, backend={}): \
              {rps_tuned:.1} req/s ({}/req)",
             best.candidate.factors, best.candidate.replicas,
             best.candidate.backend, fmt_ns(ns_tuned));
    print_percentiles("pool auto-tuned", &mut lat_tuned);
    let ratio = rps_tuned / rps_acc;
    println!("    -> auto-tuned vs default serve configuration: \
              {ratio:.2}x");
    if !smoke_mode() {
        assert!(ratio >= 1.0,
                "auto-tuned configuration slower than the default \
                 ({ratio:.2}x)");
    }

    // Retune under load: boot deliberately weak (accurate x 1) with
    // the online tuner running, keep the pool loaded until the
    // controller hot-swaps a generation, then compare request p99
    // around the swap window against the post-swap steady state —
    // what the zero-downtime handover costs the tail.
    let mut session = builder(1, BackendKind::Accurate)
        .online_tune(RetunePolicy {
            interval: Duration::from_millis(50),
            min_frames: 8,
            hysteresis: 0.01,
            cooldown: Duration::ZERO,
            max_density_spread: 10.0,
            headroom: 1.25,
        })
        .build()
        .expect("session builds");
    session.start_pool().expect("pool starts");
    let log = session.retune_log().expect("online tuner running");
    let deadline = Instant::now()
        + Duration::from_secs(if smoke_mode() { 45 } else { 120 });
    let mut rng = Rng::new(43);
    let mut swap_window_lat: Vec<u64> = Vec::new();
    while log.retunes() == 0 && Instant::now() < deadline {
        let rxs: Vec<_> = (0..4)
            .map(|_| {
                let f = SpikeFrame::random(28, 28, 16, 0.2, &mut rng);
                session.submit(f).unwrap()
            })
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            let _ = r.prediction.expect("frame served across the swap");
            swap_window_lat.push(r.latency_us);
        }
    }
    if log.retunes() == 0 {
        println!("pool online-tune: no swap before the deadline (slow \
                  host) — skipping the retune-under-load row");
    } else {
        let t0 = Instant::now();
        let rxs: Vec<_> = fs
            .iter()
            .map(|f| session.submit(f.clone()).unwrap())
            .collect();
        let mut preds_post = Vec::with_capacity(fs.len());
        let mut lat_post = Vec::with_capacity(fs.len());
        for rx in rxs {
            let r = rx.recv().unwrap();
            preds_post.push(r.prediction.unwrap());
            lat_post.push(r.latency_us);
        }
        let ns_post =
            t0.elapsed().as_nanos() as f64 / fs.len() as f64;
        set.add(BenchResult {
            name: format!("pool online-retuned (generation {})",
                          log.generation()),
            iters: n_requests,
            mean_ns: ns_post,
            median_ns: ns_post,
            min_ns: ns_post,
        });
        assert_eq!(preds1, preds_post,
                   "online retune changed predictions");
        let s = log.summary();
        println!("pool online-tune: swapped to generation {} after {} \
                  evaluation(s), predicted gain {:+.1}%",
                 s.generation, s.evaluations,
                 s.last_gain.unwrap_or(0.0) * 100.0);
        print_percentiles("retune swap window", &mut swap_window_lat);
        print_percentiles("post-swap steady", &mut lat_post);
    }
    session.shutdown();
}
