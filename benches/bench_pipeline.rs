//! Bench: whole-pipeline runs — Fig. 11 (T1 vs T2) and Fig. 12
//! (pipelining + parallelism) regeneration, constructed through the
//! `Session` facade.
//!
//! `cargo bench --bench bench_pipeline`

use sti_snn::arch;
use sti_snn::codec::SpikeFrame;
use sti_snn::session::Session;
use sti_snn::sim::cycles_to_ms;
use sti_snn::util::bench::BenchSet;
use sti_snn::util::rng::Rng;

fn frames(shape: (usize, usize, usize), n: usize) -> Vec<SpikeFrame> {
    let mut rng = Rng::new(9);
    (0..n)
        .map(|_| SpikeFrame::random(shape.0, shape.1, shape.2, 0.2,
                                    &mut rng))
        .collect()
}

fn main() {
    let mut set = BenchSet::new("pipeline (Fig. 11 / Fig. 12)");

    // SCNN3 full pipeline, T=1 vs T=2 (Fig. 11's trend at small scale).
    for t in [1usize, 2] {
        let mut session = Session::builder()
            .network(arch::scnn3())
            .timesteps(t)
            .build()
            .unwrap();
        let f = frames(session.input_shape(), 1);
        let mut vmem_kb = 0.0;
        let mut uj = 0.0;
        set.run(&format!("scnn3 frame, T={t}"), || {
            let rep = session.infer_batch(&f);
            vmem_kb = rep.layer_vmem_bytes.iter().sum::<usize>() as f64
                / 1024.0;
            uj = rep.energy_per_frame_j * 1e6;
        });
        println!("    -> Vmem {vmem_kb:.1} KB, dyn energy {uj:.1} uJ/frame");
    }

    // Fig. 12: scnn5 unpipelined vs pipelined vs parallel.
    for (name, net, pipelined) in [
        ("scnn5 unpipelined", arch::scnn5(), false),
        ("scnn5 pipelined", arch::scnn5(), true),
        ("scnn5 parallel(4,4,2,1)",
         arch::scnn5().try_with_parallel_factors(&[4, 4, 2, 1]).unwrap(),
         true),
    ] {
        let mut session = Session::builder()
            .network(net)
            .pipelined(pipelined)
            .build()
            .unwrap();
        let f = frames(session.input_shape(), 1);
        let mut modelled_ms = 0.0;
        set.run(name, || {
            let rep = session.infer_batch(&f);
            modelled_ms = if pipelined {
                cycles_to_ms(rep.t_max)
            } else {
                cycles_to_ms(rep.t_sum)
            };
        });
        println!("    -> modelled FPGA delay {modelled_ms:.2} ms/frame");
    }
}
