//! Bench: design-space exploration throughput — candidate enumeration,
//! analytical evaluation rate, Pareto pruning, and the end-to-end
//! `explore` path (plus the one genuinely simulator-bound stage, the
//! calibration probes).
//!
//! `cargo bench --bench bench_dse`
//!
//! When `STI_SNN_BENCH_DSE_JSON` is set, this bench redirects its own
//! `STI_SNN_BENCH_JSON` output there, so one `cargo bench` run ships
//! the DSE numbers as their own artifact (`BENCH_dse.json`) without
//! contaminating `BENCH_sim.json`.

use sti_snn::arch;
use sti_snn::dse::{self, CalibrationConfig, CostModel, Evaluator,
                   SearchSpace};
use sti_snn::util::bench::BenchSet;

fn main() {
    if let Ok(path) = std::env::var("STI_SNN_BENCH_DSE_JSON") {
        if !path.is_empty() {
            std::env::set_var("STI_SNN_BENCH_JSON", path);
        }
    }
    let mut set = BenchSet::new("design-space exploration (dse)");

    // scnn5 at 2x the paper budget: a few hundred exhaustive
    // candidates across 4 replica splits and both backends.
    let net = arch::scnn5();
    let model = CostModel::default();
    let space = SearchSpace::new(net.clone(), 198).with_replicas(4);

    let cands = space.enumerate(&model.timing);
    assert!(!cands.is_empty(), "empty search space");
    set.run(&format!("enumerate scnn5 ({} candidates)", cands.len()),
            || {
                let c = space.enumerate(&model.timing);
                assert_eq!(c.len(), cands.len());
            });

    let eval = Evaluator::new(&net, &model, 1);
    set.run(&format!("evaluate {} candidates", cands.len()), || {
        let mut fits = 0usize;
        for c in &cands {
            let p = eval.evaluate(c).expect("enumerated candidates valid");
            fits += p.fits as usize;
        }
        assert!(fits > 0);
    });

    let r = set.run("explore scnn5 end-to-end (enumerate+evaluate+\
                     pareto+choose)",
                    || {
                        let ex = dse::explore(&space, &model);
                        assert!(ex.chosen.is_some());
                        assert!(!ex.frontier.is_empty());
                    });
    let per_cand_ns = r.median_ns / cands.len() as f64;
    println!("    -> {:.1} candidates/ms end-to-end",
             1e6 / per_cand_ns);

    // The simulator-bound stage: probe runs + correction-factor fit on
    // scnn3 (the serving default), both backends.
    let scnn3 = arch::scnn3();
    set.run("calibrate scnn3 (sim probes, both backends)", || {
        let cal = dse::calibrate(&scnn3, &model.timing,
                                 &CalibrationConfig::default());
        assert!(cal.op_activity > 0.0);
    });
}
