//! Event-streaming benches: ingestion throughput of the windowed
//! `codec::stream` path, wire codec speed, and the headline
//! events-vs-dense end-to-end comparison the README's Performance
//! table quotes.
//!
//! ```bash
//! cargo bench --bench bench_stream
//! STI_SNN_BENCH_JSON=out.json cargo bench --bench bench_stream
//! ```

use std::time::Instant;

use sti_snn::codec::stream::{decode_events, encode_events, synth_events,
                             EventStream, WindowPolicy};
use sti_snn::codec::SpikeFrame;
use sti_snn::session::Session;
use sti_snn::sim::BackendKind;
use sti_snn::util::bench::{fmt_ns, smoke_mode, BenchResult, BenchSet};
use sti_snn::util::rng::Rng;

const WINDOW_US: u32 = 1000;

fn main() {
    ingest_and_wire();
    events_vs_dense();
    window_latency_percentiles();
}

/// Pure ingestion: sorted events -> word-packed windows, no inference.
fn ingest_and_wire() {
    let mut set = BenchSet::new(
        "event ingestion (sorted address events -> spike-frame windows)");
    let (h, w, c) = (28, 28, 16); // scnn3 post-encoder shape
    for rate in [0.05, 0.25] {
        let events = synth_events(h, w, c, 32, rate, WINDOW_US, 7);
        let n = events.len();
        let r = set.run(
            &format!("window {n} events (rate {rate}, 32 windows)"),
            || {
                let mut s = EventStream::new(
                    h, w, c, WindowPolicy::TimeUs(WINDOW_US)).unwrap();
                let mut windows = 0u64;
                for e in &events {
                    if s.push(*e).unwrap() {
                        windows += 1;
                    }
                }
                if s.flush().is_some() {
                    windows += 1;
                }
                assert_eq!(windows, 32);
            },
        );
        println!("    -> {:.1} M events/s",
                 n as f64 / (r.median_ns / 1e9) / 1e6);
    }

    let events = synth_events(h, w, c, 32, 0.15, WINDOW_US, 9);
    let bytes = encode_events(&events);
    set.run(&format!("wire decode {} events", events.len()), || {
        let decoded = decode_events(&bytes).unwrap();
        assert_eq!(decoded.len(), events.len());
    });
    set.run(&format!("wire encode {} events", events.len()), || {
        let encoded = encode_events(&events);
        assert_eq!(encoded.len(), bytes.len());
    });
}

/// End to end through the session: the same activity as dense frames
/// vs as a windowed event stream (README Performance table row).
fn events_vs_dense() {
    let mut set = BenchSet::new(
        "events vs dense end-to-end (scnn3, word-parallel)");
    let mut session = Session::builder()
        .model("scnn3")
        .backend(BackendKind::WordParallel)
        .build()
        .unwrap();
    let (h, w, c) = session.input_shape();
    let n_frames = 8usize;

    let mut rng = Rng::new(21);
    let frames: Vec<SpikeFrame> = (0..n_frames)
        .map(|_| SpikeFrame::random(h, w, c, 0.15, &mut rng))
        .collect();
    // The equivalent event stream: one synthetic window per frame at
    // the same rate (statistically matched activity).
    let events = synth_events(h, w, c, n_frames, 0.15, WINDOW_US, 21);

    let r_dense = set
        .run(&format!("dense infer_batch ({n_frames} frames)"), || {
            let rep = session.infer_batch(&frames);
            assert_eq!(rep.predictions.len(), n_frames);
        })
        .clone();
    let r_events = set
        .run(&format!("events infer_events ({n_frames} windows)"), || {
            let out = session
                .infer_events(&events, WindowPolicy::TimeUs(WINDOW_US))
                .unwrap();
            assert_eq!(out.windows.len(), n_frames);
        })
        .clone();

    let fps = |ns: f64| n_frames as f64 / (ns / 1e9);
    println!("\n    dense  {:.1} frames/s | events {:.1} windows/s \
              (ingestion overhead {:+.1}%)",
             fps(r_dense.median_ns), fps(r_events.median_ns),
             (r_events.median_ns / r_dense.median_ns - 1.0) * 100.0);

    // Streamed-schedule row-channel accounting for one batch: sends,
    // backpressure waits, and peak occupancy per inter-layer link.
    let rep = session.infer_batch(&frames);
    for (i, s) in rep.channel_stats.iter().enumerate() {
        println!("    link {i}: {} rows, {} backpressure wait(s), max \
                  occupancy {}",
                 s.sends, s.backpressure_waits, s.max_occupancy);
    }
}

/// Per-window end-to-end latency distribution (ingest one window,
/// classify it), streamed inter-layer schedule vs the serial layer
/// loop. Predictions are cross-checked — the schedules are bit-exact;
/// only wall-clock moves, and only when spare host cores exist.
fn window_latency_percentiles() {
    let mut set = BenchSet::new(
        "per-window latency, streamed vs serial (scnn3, word-parallel)");
    let n_windows = if smoke_mode() { 4 } else { 32 };
    let mut all_classes: Vec<Vec<usize>> = Vec::new();
    for (label, pipelined) in [("streamed", true), ("serial", false)] {
        let mut session = Session::builder()
            .model("scnn3")
            .backend(BackendKind::WordParallel)
            .pipelined(pipelined)
            .build()
            .unwrap();
        let (h, w, c) = session.input_shape();
        let events = synth_events(h, w, c, n_windows, 0.15, WINDOW_US, 33);
        let mut stream = session
            .event_stream(WindowPolicy::TimeUs(WINDOW_US))
            .unwrap();
        let mut lat_ns: Vec<f64> = Vec::new();
        let mut classes = Vec::new();
        let mut classify = |session: &mut Session, frame: SpikeFrame| {
            let t = Instant::now();
            let inf = session.infer(frame).unwrap();
            lat_ns.push(t.elapsed().as_nanos() as f64);
            classes.push(inf.class);
        };
        for e in &events {
            if stream.push(*e).unwrap() {
                let frame = stream.window().clone();
                classify(&mut session, frame);
            }
        }
        if let Some(f) = stream.flush() {
            let frame = f.clone();
            classify(&mut session, frame);
        }
        drop(classify);
        lat_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| {
            lat_ns[((lat_ns.len() - 1) as f64 * p).round() as usize]
        };
        println!("window latency [{label}]: p50 {} / p95 {} / p99 {} \
                  ({} windows)",
                 fmt_ns(pct(0.50)), fmt_ns(pct(0.95)), fmt_ns(pct(0.99)),
                 lat_ns.len());
        set.add(BenchResult {
            name: format!("window latency [{label}]"),
            iters: lat_ns.len(),
            mean_ns: lat_ns.iter().sum::<f64>() / lat_ns.len() as f64,
            median_ns: pct(0.50),
            min_ns: lat_ns[0],
        });
        all_classes.push(classes);
    }
    assert_eq!(all_classes[0], all_classes[1],
               "streamed and serial schedules diverged on predictions");
}
