//! Event-streaming benches: ingestion throughput of the windowed
//! `codec::stream` path, wire codec speed, and the headline
//! events-vs-dense end-to-end comparison the README's Performance
//! table quotes.
//!
//! ```bash
//! cargo bench --bench bench_stream
//! STI_SNN_BENCH_JSON=out.json cargo bench --bench bench_stream
//! ```

use sti_snn::codec::stream::{decode_events, encode_events, synth_events,
                             EventStream, WindowPolicy};
use sti_snn::codec::SpikeFrame;
use sti_snn::session::Session;
use sti_snn::sim::BackendKind;
use sti_snn::util::bench::BenchSet;
use sti_snn::util::rng::Rng;

const WINDOW_US: u32 = 1000;

fn main() {
    ingest_and_wire();
    events_vs_dense();
}

/// Pure ingestion: sorted events -> word-packed windows, no inference.
fn ingest_and_wire() {
    let mut set = BenchSet::new(
        "event ingestion (sorted address events -> spike-frame windows)");
    let (h, w, c) = (28, 28, 16); // scnn3 post-encoder shape
    for rate in [0.05, 0.25] {
        let events = synth_events(h, w, c, 32, rate, WINDOW_US, 7);
        let n = events.len();
        let r = set.run(
            &format!("window {n} events (rate {rate}, 32 windows)"),
            || {
                let mut s = EventStream::new(
                    h, w, c, WindowPolicy::TimeUs(WINDOW_US)).unwrap();
                let mut windows = 0u64;
                for e in &events {
                    if s.push(*e).unwrap() {
                        windows += 1;
                    }
                }
                if s.flush().is_some() {
                    windows += 1;
                }
                assert_eq!(windows, 32);
            },
        );
        println!("    -> {:.1} M events/s",
                 n as f64 / (r.median_ns / 1e9) / 1e6);
    }

    let events = synth_events(h, w, c, 32, 0.15, WINDOW_US, 9);
    let bytes = encode_events(&events);
    set.run(&format!("wire decode {} events", events.len()), || {
        let decoded = decode_events(&bytes).unwrap();
        assert_eq!(decoded.len(), events.len());
    });
    set.run(&format!("wire encode {} events", events.len()), || {
        let encoded = encode_events(&events);
        assert_eq!(encoded.len(), bytes.len());
    });
}

/// End to end through the session: the same activity as dense frames
/// vs as a windowed event stream (README Performance table row).
fn events_vs_dense() {
    let mut set = BenchSet::new(
        "events vs dense end-to-end (scnn3, word-parallel)");
    let mut session = Session::builder()
        .model("scnn3")
        .backend(BackendKind::WordParallel)
        .build()
        .unwrap();
    let (h, w, c) = session.input_shape();
    let n_frames = 8usize;

    let mut rng = Rng::new(21);
    let frames: Vec<SpikeFrame> = (0..n_frames)
        .map(|_| SpikeFrame::random(h, w, c, 0.15, &mut rng))
        .collect();
    // The equivalent event stream: one synthetic window per frame at
    // the same rate (statistically matched activity).
    let events = synth_events(h, w, c, n_frames, 0.15, WINDOW_US, 21);

    let r_dense = set
        .run(&format!("dense infer_batch ({n_frames} frames)"), || {
            let rep = session.infer_batch(&frames);
            assert_eq!(rep.predictions.len(), n_frames);
        })
        .clone();
    let r_events = set
        .run(&format!("events infer_events ({n_frames} windows)"), || {
            let out = session
                .infer_events(&events, WindowPolicy::TimeUs(WINDOW_US))
                .unwrap();
            assert_eq!(out.windows.len(), n_frames);
        })
        .clone();

    let fps = |ns: f64| n_frames as f64 / (ns / 1e9);
    println!("\n    dense  {:.1} frames/s | events {:.1} windows/s \
              (ingestion overhead {:+.1}%)",
             fps(r_dense.median_ns), fps(r_events.median_ns),
             (r_events.median_ns / r_dense.median_ns - 1.0) * 100.0);
}
