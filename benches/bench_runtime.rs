//! Bench: PJRT runtime — HLO compile + execute latency for the AOT
//! artifacts (the functional-reference path of the e2e driver).
//!
//! Requires `make artifacts` AND a binary built with the `pjrt`
//! feature; skips gracefully when either is absent (the default build
//! compiles the runtime as an erroring stub).
//!
//! `cargo bench --bench bench_runtime`

use sti_snn::model::Artifact;
use sti_snn::runtime::{artifacts_dir, Runtime};
use sti_snn::util::bench::BenchSet;
use sti_snn::util::rng::Rng;

fn main() {
    if !cfg!(feature = "pjrt") {
        println!("bench_runtime: built without the `pjrt` feature (the \
                  runtime is a stub); skipping");
        return;
    }
    let dir = artifacts_dir().join("scnn3");
    if !dir.join("model.hlo.txt").exists() {
        println!("bench_runtime: artifacts/scnn3 missing — run `make \
                  artifacts` first; skipping");
        return;
    }
    let art = Artifact::load(&dir).expect("artifact loads");
    let mut set = BenchSet::new("PJRT runtime (AOT artifacts)");

    let mut compile_rt = None;
    set.run("compile encoder+model HLO", || {
        let mut rt = Runtime::new().unwrap();
        rt.load_hlo("encoder", &art.encoder_hlo(), art.net.input).unwrap();
        rt.load_hlo("model", &art.model_hlo(), art.net.input).unwrap();
        compile_rt = Some(rt);
    });
    let rt = compile_rt.unwrap();

    let (h, w, c) = art.net.input;
    let mut rng = Rng::new(5);
    let image: Vec<f32> = (0..h * w * c).map(|_| rng.f32()).collect();

    set.run("encoder execute (image -> spikes)", || {
        std::hint::black_box(
            rt.encode("encoder", &image, art.encoder_out_shape()).unwrap());
    });

    set.run("full model execute (image -> logits)", || {
        std::hint::black_box(rt.logits("model", &image).unwrap());
    });
}
